"""End-to-end LM training driver: ~100M-param model, a few hundred steps.

Uses the real production stack (config registry, sharded loader, jitted
AdamW train step, checkpoint/restart).  The default below is a ~100M-param
phi4-mini-family model; loss must drop measurably.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses

from repro.configs import base as cfgbase
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="phi4_mini")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 8 layers x d512 x ff2048, 32k vocab, same family
    cfg = dataclasses.replace(
        cfgbase.get_config(args.arch),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32_768, window=256)
    print(f"training {cfg.name}-family model: "
          f"{cfg.param_count()/1e6:.0f}M params")

    out = train(args.arch, config=cfg, steps=args.steps,
                global_batch=8, seq_len=256, lr=6e-4,
                ckpt_dir=args.ckpt_dir, ckpt_every=100)
    drop = out["first_loss"] - out["last_loss"]
    print(f"loss {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"(drop {drop:.3f})")
    assert drop > 0.3, "training did not learn"


if __name__ == "__main__":
    main()
