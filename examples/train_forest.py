"""Quickstart: the full ensemble loop — train a random forest on the
supervised farm, publish it to the versioned registry with its OOB score,
canary a retrained candidate onto live traffic, promote it, and serve
predictions through the microbatched service.

  PYTHONPATH=src python examples/train_forest.py
"""

import tempfile

import numpy as np

from repro.core import GrowConfig
from repro.data import quest
from repro.ensemble import ForestConfig, publish_forest, train_forest
from repro.infer import registry
from repro.infer.service import (BatchPredictService, InferReplica,
                                 PredictRequest)
from repro.obs.metrics import Registry


def main() -> None:
    ds = quest.generate(5_000, function=5, seed=0, perturbation=0.02)
    grow = GrowConfig(max_nodes=1 << 14)

    # -- train: one farm task per tree; the forest is a pure function of
    #    (dataset, config), independent of worker count or faults
    fc = ForestConfig(n_trees=8, seed=0, grow=grow)
    stats = {}
    result = train_forest(ds, fc, n_workers=4, stats_out=stats)
    print(f"forest           : {result.n_trees} trees "
          f"(mtry {fc.resolved_mtry(ds.n_attrs)} of {ds.n_attrs} attrs)")
    print(f"throughput       : {stats['trees_per_s']:.2f} trees/s "
          f"on {len(stats['worker_tasks'])} workers")

    with tempfile.TemporaryDirectory() as root:
        # -- publish: pack + atomic registry publish, OOB score in the
        #    manifest, keep only the last few versions on disk
        v1 = publish_forest(root, "rf", result, ds, keep_last=4)
        meta = registry.manifest_of(v1)["metadata"]
        print(f"published        : {v1.rsplit('/', 1)[-1]} "
              f"(oob {meta['oob_score']:.4f}, "
              f"coverage {meta['oob_coverage']:.3f})")
        handle = registry.ModelHandle(root, "rf")

        # -- canary: retrain a candidate (more trees), publish, route 25%
        #    of uids onto it, then promote when its OOB is no worse
        fc2 = ForestConfig(n_trees=12, seed=1, grow=grow)
        result2 = train_forest(ds, fc2, n_workers=4)
        v2 = publish_forest(root, "rf", result2, ds, keep_last=4)
        meta2 = registry.manifest_of(v2)["metadata"]
        handle.set_canary(v2, 0.25)
        print(f"canary           : {v2.rsplit('/', 1)[-1]} "
              f"(oob {meta2['oob_score']:.4f}) on 25% of uids")
        if meta2["oob_score"] >= meta["oob_score"]:
            handle.promote_canary()
            print(f"promoted         : stable is now "
                  f"{handle.stable_path.rsplit('/', 1)[-1]}")

        # -- serve: microbatched predictions through the replica fleet;
        #    replicas resolve models through the handle, so the promotion
        #    above already reaches them
        metrics = Registry()
        svc = BatchPredictService(
            [InferReplica.from_handle(handle, ds.attr_is_cont)
             for _ in range(3)],
            handle=handle, policy="ws", max_batch=128, metrics=metrics)
        for uid in range(2_000):
            svc.submit(PredictRequest(uid=uid, x_row=ds.x[uid % ds.n_cases]))
        results = svc.run_until_drained()
        stats = svc.stats()
        got = np.array([r.label for r in sorted(results, key=lambda r: r.uid)])
        acc = (got == ds.y[np.arange(2_000) % ds.n_cases]).mean()
        print(f"served           : {len(results)} predictions, "
              f"{stats['failed']} failures in {stats['ticks']} ticks, "
              f"accuracy {acc:.4f}")


if __name__ == "__main__":
    main()
