"""Batched serving example: 2 replicas, WS-scheduled continuous batching.

The request scheduler is the paper's weighted-scheduling policy (weight =
prompt length + budget), dispatching across model replicas exactly like the
YaDT-FF emitter dispatches node tasks across workers.

  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import serve


def main() -> None:
    out = serve("gemma2_9b", reduced=True, n_requests=12, n_replicas=2,
                n_slots=3, max_new=8, policy="ws")
    print(f"completed {out['completed']} requests / {out['tokens']} tokens "
          f"in {out['seconds']:.1f}s  ({out['tok_per_s']:.1f} tok/s)")
    assert out["completed"] == 12


if __name__ == "__main__":
    main()
