"""Quickstart: serve a trained ensemble through the inference stack —
pack trees into a Forest, publish to the versioned registry, canary a
candidate, and drain a microbatched predict workload over replicas.

  PYTHONPATH=src python examples/predict_service.py
"""

import tempfile

import numpy as np

from repro.core import GrowConfig, c45
from repro.data import quest
from repro.infer import forest as F
from repro.infer import registry
from repro.infer.service import (BatchPredictService, InferReplica,
                                 PredictRequest)
from repro.obs.metrics import Registry


def main() -> None:
    ds = quest.generate(10_000, function=5, seed=0, perturbation=0.02)
    rng = np.random.default_rng(0)

    # a small bagged ensemble, packed into one padded SoA Forest
    trees = [c45.build(ds.subset(rng.choice(ds.n_cases, ds.n_cases)),
                       GrowConfig(max_nodes=1 << 14)) for _ in range(4)]
    ensemble = F.Forest.pack(trees)
    pred = np.asarray(F.predict(ensemble, ds.x, ds.attr_is_cont))
    print(f"ensemble         : {ensemble.n_trees} trees, "
          f"capacity {ensemble.capacity} nodes")
    print(f"train accuracy   : {(pred == ds.y).mean():.4f}")

    with tempfile.TemporaryDirectory() as root:
        # atomic publish, then pin a serving handle on the stable version
        registry.publish(root, "quest", ensemble,
                         metadata={"note": "bagged x4"})
        handle = registry.ModelHandle(root, "quest")

        # a new candidate lands as v2; canary 20% of uids onto it
        # (promote_canary() / refresh() would make it stable later)
        candidate = F.Forest.pack(trees[:2])
        v2 = registry.publish(root, "quest", candidate)
        handle.set_canary(v2, 0.2)
        print(f"stable version   : {handle.stable_path.rsplit('/', 1)[-1]}")

        metrics = Registry()
        svc = BatchPredictService(
            [InferReplica.from_handle(handle, ds.attr_is_cont)
             for _ in range(3)],
            handle=handle, policy="ws", max_batch=128, max_wait_ticks=4,
            metrics=metrics)
        for uid in range(2_000):
            svc.submit(PredictRequest(uid=uid, x_row=ds.x[uid % ds.n_cases]))
        results = svc.run_until_drained()

        stats = svc.stats()
        served = {a: metrics.get("infer_results_total").value(arm=a)
                  for a in ("stable", "canary")}
        print(f"drained          : {len(results)} results, "
              f"{stats['failed']} failures in {stats['ticks']} ticks")
        print(f"arm split        : {served}")
        hist = metrics.get("infer_batch_rows")._snapshot_series()[0]
        print(f"batch shape      : {hist['count']} batches, "
              f"mean {hist['sum'] / hist['count']:.1f} rows")


if __name__ == "__main__":
    main()
