"""Quickstart: grow a C4.5 tree with the SPMD frontier engine (the paper's
technique) on QUEST data, check it against the sequential YaDT oracle, and
predict.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import GrowConfig, predict, trees_equal
from repro.core import c45, frontier
from repro.data import quest


def main() -> None:
    # SyD-style dataset (paper Table 1 schema), scaled for a laptop
    ds = quest.generate(20_000, function=5, seed=0, perturbation=0.02)
    cfg = GrowConfig(max_nodes=1 << 14, frontier_slots=128)

    trace = []
    tree_seq = c45.build(ds, cfg, task_trace=trace, capacity=cfg.max_nodes)
    tree_ff = frontier.build(ds, cfg)              # NP/NAP SPMD engine
    print(f"sequential YaDT : {tree_seq.size} nodes, depth {tree_seq.depth}")
    print(f"frontier  YaDT-FF: {tree_ff.size} nodes, depth {tree_ff.depth}")
    print(f"identical trees  : {trees_equal(tree_seq, tree_ff)}")

    pred = np.asarray(predict(tree_ff, ds.x, ds.attr_is_cont))
    print(f"train accuracy   : {(pred == ds.y).mean():.4f}")

    # the farm view of the same build (paper Sect. 4): simulate 8 workers
    from repro.core import simulate
    cm = simulate.calibrate(trace, measured_seq_seconds=1.0)
    for strategy in ("np", "nap"):
        r = simulate.simulate(trace, n_workers=8, strategy=strategy,
                              policy="ws", cost=cm)
        print(f"{strategy.upper():3s} strategy, 8 workers: "
              f"simulated speedup {r.speedup:.2f}x")


if __name__ == "__main__":
    main()
