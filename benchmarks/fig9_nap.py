"""Paper Fig. 9: NAP-strategy speedup vs number of farm workers."""

from benchmarks.common import emit
from benchmarks.fig8_np import run as run_np


def run() -> list[dict]:
    return run_np(strategy="nap", tag="fig9_nap")


if __name__ == "__main__":
    emit(run())
