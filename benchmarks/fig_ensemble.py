"""Ensemble training throughput: trees/sec vs farm workers, OOB trajectory.

Trains the same random forest (fixed ``(dataset, ForestConfig)``, hence the
same trees bit-for-bit every run) over the supervised farm at several worker
counts and times each run.  Tree tasks are embarrassingly parallel, so this
is the ensemble's outer-level answer to the paper's inner-level
(nodes/attributes) speedup figures — with the same caveat the paper makes
for its pthread baseline: the c45 oracle engine is Python, so thread-farm
speedup is bounded by how much of the build releases the GIL (numpy
kernels).  The figure records the honest trees/sec trajectory; the
process-level (or ``impl="frontier"`` jit) path is where large speedups
live.

Second panel: the OOB trajectory — the out-of-bag error re-scored on the
first ``k`` trees for growing ``k``, showing the usual fast-then-flat
convergence that justifies the forest width.

Emits the usual CSV rows *and* a ``BENCH_ensemble.json`` artifact (path
overridable via ``BENCH_OUT``) gated by ``benchmarks/check_regression.py``
against the committed baseline.

Knobs for CI smoke runs (all env vars):

  * ``BENCH_SCALE``            — global dataset scale multiplier (common.py);
  * ``BENCH_ENSEMBLE_TREES``   — forest width (default 6);
  * ``BENCH_ENSEMBLE_WORKERS`` — comma list of worker counts (default
    ``1,2,4``).
"""

from __future__ import annotations

import json
import os
import sys

import jax

if __package__ in (None, ""):      # `python benchmarks/fig_ensemble.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from repro.core.config import GrowConfig
from repro.data import datasets
from repro.ensemble import ForestConfig, oob_score, train_forest
from repro.obs.metrics import Registry

DATASET = "syd10m9a"          # QUEST stand-in: 9 attrs, deep trees (Table 1)
MAX_BINS = 32
N_TREES = int(os.environ.get("BENCH_ENSEMBLE_TREES", "6"))
WORKERS = tuple(int(v) for v in os.environ.get(
    "BENCH_ENSEMBLE_WORKERS", "1,2,4").split(","))
GROW = GrowConfig(max_nodes=1 << 14)
#: Ensemble runs N_TREES full builds per worker count — use a quarter of the
#: common dataset scale so the whole figure stays within a CPU budget (the
#: scaling *shape* is what matters; mtry trees are deeper than single-tree
#: builds at equal N).
SCALE = 0.25 * common.SCALES[DATASET]


def run() -> list[dict]:
    ds = datasets.load(DATASET, scale=SCALE, seed=0, max_bins=MAX_BINS)
    fc = ForestConfig(n_trees=N_TREES, seed=0, grow=GROW)
    registry = Registry()

    # -- panel 1: trees/sec vs workers (same forest every time) -------------
    steps: list[dict] = []
    result = None
    for n_workers in WORKERS:
        stats: dict = {}
        result, secs = common.timed(
            lambda nw=n_workers, st=stats: train_forest(
                ds, fc, n_workers=nw, stats_out=st, metrics=registry),
            repeats=1)
        # One shared timing key across both panels: check_regression sums
        # each t_*_s key over every common step, so heterogeneous step
        # types must agree on the key set.
        steps.append({
            "step": f"w{n_workers}",
            "n_workers": n_workers,
            "t_step_s": secs,
            "trees_per_s": stats["trees_per_s"],
            "n_trees": result.n_trees,
        })

    # -- panel 2: OOB trajectory over the first k trees ---------------------
    oob_steps: list[dict] = []
    ks = sorted({max(1, N_TREES // 4), max(1, N_TREES // 2), N_TREES})
    for k in ks:
        fck = ForestConfig(n_trees=k, seed=0, grow=GROW)
        r, secs = common.timed(
            lambda trees=result.trees[:k], cfg=fck: oob_score(
                trees, ds, cfg, metrics=registry),
            repeats=1)
        oob_steps.append({
            "step": f"oob_k{k}",
            "k": k,
            "t_step_s": secs,
            "oob_score": r.score,
            "oob_coverage": r.coverage,
        })

    artifact = {
        "dataset": DATASET,
        "scale": SCALE,
        "n_cases": ds.n_cases,
        "n_attrs": ds.n_attrs,
        "max_bins": MAX_BINS,
        "backend": jax.default_backend(),
        "n_trees": N_TREES,
        "mtry": fc.resolved_mtry(ds.n_attrs),
        "workers": list(WORKERS),
        "steps": steps + oob_steps,
        "metrics": registry.snapshot(),
    }
    out_path = os.environ.get("BENCH_OUT", "BENCH_ensemble.json")
    with open(out_path, "w") as f:
        json.dump(artifact, f, indent=1)

    rows = []
    for s in steps:
        rows.append({
            "name": f"ensemble/train_w{s['n_workers']}",
            "us_per_call": f"{s['t_step_s'] * 1e6:.1f}",
            "trees_per_s": f"{s['trees_per_s']:.3f}",
            "n_trees": s["n_trees"],
            "dataset": DATASET,
        })
    if len(steps) >= 2:
        rows.append({
            "name": "ensemble/scaling",
            "us_per_call": "",
            "speedup": f"{steps[0]['t_step_s'] / steps[-1]['t_step_s']:.2f}",
            "workers": f"{steps[0]['n_workers']}->{steps[-1]['n_workers']}",
            "artifact": out_path,
        })
    for s in oob_steps:
        rows.append({
            "name": f"ensemble/{s['step']}",
            "us_per_call": f"{s['t_step_s'] * 1e6:.1f}",
            "oob_score": f"{s['oob_score']:.4f}",
            "coverage": f"{s['oob_coverage']:.3f}",
        })
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    common.emit(run())
