"""Paper Table 2: YaDT vs YaDT-FF on a quad-core (1 emitter + 1..3 workers),
plus this port's own headline: the vectorized SPMD engine vs the sequential
oracle on the same data (real wall clock, not simulated)."""

from __future__ import annotations

from benchmarks.common import GROW, build_with_trace, emit, load_scaled, timed
from repro.core import frontier, simulate
from repro.data import datasets


def run() -> list[dict]:
    rows = []
    for name in datasets.TABLE1:
        ds = load_scaled(name)
        _, trace, cm, seq_s = build_with_trace(ds)
        cols = {}
        for w in (1, 2, 3):
            r = simulate.simulate(trace, n_workers=w, strategy="nap",
                                  policy="ws", cost=cm)
            cols[f"t_1E{w}W"] = round(r.makespan, 4)
        boost = seq_s / cols["t_1E3W"] if cols["t_1E3W"] else 0.0
        # real measured boost of this port: jit'd frontier engine wall clock
        _, ff_s = timed(lambda: frontier.build(ds, GROW), repeats=3)
        rows.append(dict(name=f"table2/{name}",
                         us_per_call=f"{seq_s*1e6:.0f}",
                         seq_time=round(seq_s, 4), **cols,
                         max_boost=round(boost, 2),
                         frontier_time=round(ff_s, 4),
                         frontier_boost=round(seq_s / ff_s, 2)))
    return rows


if __name__ == "__main__":
    emit(run())
