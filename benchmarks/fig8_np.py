"""Paper Fig. 8: NP-strategy speedup vs number of farm workers.

Replay of the real task DAG (recorded from the sequential build on each
scaled Table-1 dataset) through the farm simulator with per-task costs
calibrated to the measured sequential time (see core/simulate.py).
"""

from __future__ import annotations

from benchmarks.common import build_with_trace, emit, load_scaled
from repro.core import simulate
from repro.data import datasets

WORKERS = (1, 2, 3, 4, 5, 6, 7, 8)


def run(strategy: str = "np", tag: str = "fig8_np") -> list[dict]:
    rows = []
    for name in datasets.TABLE1:
        ds = load_scaled(name)
        tree, trace, cm, seq_s = build_with_trace(ds)
        speedups = {}
        for w in WORKERS:
            r = simulate.simulate(trace, n_workers=w, strategy=strategy,
                                  policy="ws", cost=cm)
            speedups[f"w{w}"] = round(r.speedup, 3)
        rows.append(dict(name=f"{tag}/{name}",
                         us_per_call=f"{seq_s*1e6:.0f}",
                         nodes=tree.size, **speedups))
    return rows


if __name__ == "__main__":
    emit(run())
