#!/usr/bin/env bash
# CI smoke: benchmark suite at 1/10 scale + the tier-1 test suite.
#
#   benchmarks/smoke.sh            # everything
#   ONLY=fig_superstep benchmarks/smoke.sh   # filter benchmark modules
#
# BENCH_SCALE shrinks every Table-1 stand-in (common.SCALES); 0.1 keeps the
# whole run CPU-viable while preserving tree/task-DAG shape.
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_SCALE="${BENCH_SCALE:-0.1}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== benchmarks (BENCH_SCALE=${BENCH_SCALE}) =="
python -m benchmarks.run ${ONLY:+--only "$ONLY"}

echo "== tier-1 tests =="
python -m pytest -x -q
