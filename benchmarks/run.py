"""Benchmark entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig8,table2]

Prints ``name,us_per_call,derived`` CSV rows (stdout), one per measurement.
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = (
    "table1_datasets",
    "fig8_np",
    "fig9_nap",
    "fig10_11_scalability",
    "fig12_cost_models",
    "fig13_scheduling",
    "fig_superstep",
    "fig_infer",
    "fig_ensemble",
    "fig_faults",
    "table2_quadcore",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substring filter")
    args = ap.parse_args()
    import importlib

    from benchmarks.common import emit
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and not any(s in mod_name
                                 for s in args.only.split(",")):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            emit(mod.run())
        except Exception:
            failed.append(mod_name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
