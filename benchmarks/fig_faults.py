"""Fault-tolerance overhead: farm C4.5 build under injected crash rates.

Measures what supervision costs when nothing fails (crash_p=0) and how
build time + farm failure breakdown scale as the injected per-attempt crash
probability rises, with one permanently dead worker in the worst row.  The
built tree is verified oracle-equal in every row — fault tolerance is only
interesting if the answer stays exact.
"""

from __future__ import annotations

import time

from benchmarks.common import GROW as CFG
from benchmarks.common import emit, load_scaled
from repro.core import c45, faults, farm_build
from repro.core.farm import FaultPolicy
from repro.core.tree import trees_equal

N_WORKERS = 4
ROWS = (
    ("p0", 0.0, frozenset()),
    ("p05", 0.05, frozenset()),
    ("p20", 0.2, frozenset()),
    ("p20_dead1", 0.2, frozenset({1})),
)


def run() -> list[dict]:
    ds = load_scaled("forest_cover")
    t0 = time.perf_counter()
    oracle = c45.build(ds, CFG)
    seq_s = time.perf_counter() - t0

    rows = []
    for name, crash_p, dead in ROWS:
        inj = faults.FaultInjector(seed=7, spec=faults.FaultSpec(
            crash_p=crash_p, dead_workers=dead),
            key_fn=lambda t: t.node_id)
        stats: dict = {}
        t0 = time.perf_counter()
        tree = farm_build.build(
            ds, CFG, n_workers=N_WORKERS, injector=inj,
            fault=FaultPolicy(max_retries=10, backoff_base=1e-4),
            stats_out=stats)
        dt = time.perf_counter() - t0
        rows.append(dict(
            name=f"fig_faults/{name}",
            us_per_call=f"{dt * 1e6:.0f}",
            oracle_equal=bool(trees_equal(oracle, tree)),
            overhead_vs_seq=round(dt / seq_s, 3),
            failures=stats["failures"],
            retries=stats["retries"],
            requeues=stats["requeues"],
            quarantined=stats["quarantined"],
            dead_workers=len(stats["dead_workers"]),
        ))
    return rows


if __name__ == "__main__":
    emit(run())
