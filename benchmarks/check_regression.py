"""Gate benchmark runs against a committed ``BENCH_*.json`` baseline.

Compares the per-step timing trajectory a figure script just produced
(``BENCH_OUT``) with the baseline committed in the repo, and exits
nonzero when any variant's mean time over the *common* steps regressed
past ``--tolerance`` (a fraction: 0.15 = +15%).

Structural keys (dataset, n_attrs, max_bins, frontier_slots) must match —
a timing diff between different problems is noise, so that's an error.
Environment keys (backend, scale, n_cases) may legitimately differ between
a CI smoke run and the committed full-size baseline; they are reported as
warnings and the caller widens ``--tolerance`` accordingly (CI passes a
deliberately generous one — the smoke gate is for order-of-magnitude
blowups and broken artifacts, not microbenchmark precision).

Usage::

    python benchmarks/check_regression.py \
        --baseline BENCH_superstep.json --current bench_current.json \
        [--tolerance 0.15]

``--baseline``/``--current`` may be repeated to gate several artifacts in
one invocation (pairs are matched positionally); the gate fails if any
pair fails::

    python benchmarks/check_regression.py \
        --baseline BENCH_superstep.json --current cur_superstep.json \
        --baseline BENCH_infer.json     --current cur_infer.json
"""

from __future__ import annotations

import argparse
import json
import sys

#: These must agree or the comparison is meaningless.
STRUCTURAL = ("dataset", "n_attrs", "max_bins", "frontier_slots")
#: These may differ (smoke vs full baseline) — warn, don't fail.
ENVIRONMENT = ("backend", "scale", "n_cases", "compact_min_bucket")


def compare(baseline: dict, current: dict,
            tolerance: float) -> tuple[list[str], list[str]]:
    """Return (errors, warnings); empty errors = gate passes."""
    errors: list[str] = []
    warnings: list[str] = []

    for k in STRUCTURAL:
        b, c = baseline.get(k), current.get(k)
        if b is not None and c is not None and b != c:
            errors.append(f"structural mismatch: {k}={c!r} "
                          f"(baseline {b!r})")
    if errors:
        return errors, warnings
    for k in ENVIRONMENT:
        b, c = baseline.get(k), current.get(k)
        if b is not None and c is not None and b != c:
            warnings.append(f"environment differs: {k}={c!r} "
                            f"(baseline {b!r})")

    by_step = {s["step"]: s for s in baseline.get("steps", [])}
    common = [(by_step[s["step"]], s) for s in current.get("steps", [])
              if s["step"] in by_step]
    if not common:
        errors.append("no common steps between baseline and current run")
        return errors, warnings

    keys = sorted(k for k in common[0][0]
                  if k.startswith("t_") and k.endswith("_s")
                  and k in common[0][1])
    if not keys:
        errors.append("no common t_*_s timing keys")
        return errors, warnings

    for k in keys:
        base = sum(b[k] for b, _ in common) / len(common)
        cur = sum(c[k] for _, c in common) / len(common)
        ratio = cur / base if base > 0 else float("inf")
        line = (f"{k:24s} baseline {base * 1e6:10.1f}us  "
                f"current {cur * 1e6:10.1f}us  x{ratio:.3f}  "
                f"({len(common)} steps)")
        if ratio > 1.0 + tolerance:
            errors.append(f"REGRESSION {line}  (tolerance +{tolerance:.0%})")
        else:
            warnings.append(f"ok         {line}")
    return errors, warnings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", action="append", required=True,
                    help="committed BENCH_*.json to compare against "
                         "(repeatable; paired positionally with --current)")
    ap.add_argument("--current", action="append", required=True,
                    help="artifact the benchmark run just wrote (BENCH_OUT; "
                         "repeatable)")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed fractional slowdown (default 0.15 = +15%%)")
    args = ap.parse_args(argv)
    if len(args.baseline) != len(args.current):
        ap.error(f"{len(args.baseline)} --baseline vs "
                 f"{len(args.current)} --current: pairs must match")

    failed = 0
    for base_path, cur_path in zip(args.baseline, args.current):
        with open(base_path) as f:
            baseline = json.load(f)
        with open(cur_path) as f:
            current = json.load(f)
        errors, notes = compare(baseline, current, args.tolerance)
        for n in notes:
            print(n)
        for e in errors:
            print(e, file=sys.stderr)
        if errors:
            print(f"FAIL: {len(errors)} problem(s) vs {base_path}",
                  file=sys.stderr)
            failed += 1
        else:
            print(f"PASS: within +{args.tolerance:.0%} of {base_path}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
