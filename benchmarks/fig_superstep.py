"""Per-superstep splitAtt timing: jnp vs pallas vs pallas+compaction.

Replays one frontier build's superstep trajectory (driven by the jnp
reference engine so every variant sees the *same* states) and times each
splitAtt implementation at every step, recording the live-case count.  The
point of the figure: with active-case compaction the pallas superstep cost
tracks ``n_active`` (the open frontier's live cases) while the all-N path
stays flat at O(N) — the deep-tree half of the build stops paying full-HBM
traffic to count a handful of rows.

Emits the usual CSV rows *and* writes a ``BENCH_superstep.json`` trajectory
artifact (path overridable via ``BENCH_OUT``) so later PRs can diff perf
against this baseline — ``benchmarks/check_regression.py`` is the gate.

Knobs for CI smoke runs (all env vars):

  * ``BENCH_SCALE``     — global dataset scale multiplier (common.py);
  * ``BENCH_MAX_STEPS`` — cap on replayed supersteps (default 48);
  * ``BENCH_VARIANTS``  — comma list of variants to time; ``jnp`` always
    runs (it drives the shared state trajectory);
  * ``TRACE_OUT``       — if set, saves a Perfetto-loadable trace of the
    replay (one span per timed variant call, ``n_active`` counter track).

The artifact also embeds a ``metrics`` snapshot (per-variant superstep
histograms from :mod:`repro.obs.metrics`).

Off-TPU the kernels run in interpret mode, so absolute pallas-vs-jnp times
are meaningless there (the JSON records the backend); the compaction-vs-full
ratio on deep supersteps is meaningful everywhere — both sides run the same
kernel, only the case-tile grid differs.
"""

from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):      # `python benchmarks/fig_superstep.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from repro.core import frontier
from repro.core.config import GrowConfig
from repro.core.frontier import FrontierProblem
from repro.data import datasets
from repro.kernels import compaction
from repro.obs.metrics import Registry
from repro.obs.trace import Tracer

DATASET = "syd10m9a"          # QUEST stand-in: 9 attrs, deep tree (Table 1)
MAX_BINS = 32                 # keeps interpret-mode grids CPU-viable
MAX_STEPS = int(os.environ.get("BENCH_MAX_STEPS", "48"))
MIN_BUCKET = 256


def _variants(ds):
    base = dict(max_nodes=1 << 14, frontier_slots=64,
                compact_min_bucket=MIN_BUCKET)
    all_v = {
        "jnp": (GrowConfig(**base), "jnp"),
        "pallas": (GrowConfig(**base, compact=False), "pallas"),
        "pallas_compact": (GrowConfig(**base, compact=True), "pallas"),
    }
    want = os.environ.get("BENCH_VARIANTS")
    if not want:
        return all_v
    keep = {v.strip() for v in want.split(",")} | {"jnp"}   # jnp drives
    unknown = keep - set(all_v)
    if unknown:
        raise SystemExit(f"BENCH_VARIANTS: unknown {sorted(unknown)} "
                         f"(have {sorted(all_v)})")
    return {k: v for k, v in all_v.items() if k in keep}


def run() -> list[dict]:
    ds = datasets.load(DATASET, scale=common.SCALES[DATASET], seed=0,
                       max_bins=MAX_BINS)
    x = jnp.asarray(ds.x)
    y = jnp.asarray(ds.y)
    w = jnp.asarray(ds.w, jnp.float32)
    cont = jnp.asarray(ds.attr_is_cont)
    nb = jnp.asarray(ds.n_bins, jnp.int32)

    variants = _variants(ds)
    steps_fns = {}
    for vname, (cfg, impl) in variants.items():
        prob = FrontierProblem.from_dataset(ds, cfg)
        steps_fns[vname] = jax.jit(frontier._superstep_fn(prob, impl))

    drive_cfg, _ = variants["jnp"]
    drive_prob = FrontierProblem.from_dataset(ds, drive_cfg)
    state = frontier.init_state(drive_prob, y, w)

    trace_out = os.environ.get("TRACE_OUT")
    tracer = Tracer(enabled=bool(trace_out))
    registry = Registry()
    m_step = registry.histogram(
        "bench_superstep_seconds", "timed superstep call, variant= label")

    steps: list[dict] = []
    i = 0
    while bool(jnp.any(state.status == 1)) and i < MAX_STEPS:
        row = {"step": i,
               "n_open": int(jnp.sum((state.status == 1).astype(jnp.int32)))}
        for vname, fn in steps_fns.items():
            with tracer.span(f"superstep.{vname}", step=i):
                (_, stats), secs = common.timed(fn, state, x, y, w, cont, nb,
                                                repeats=3)
            row[f"t_{vname}_s"] = secs
            row["n_active"] = int(stats["n_active"])
            m_step.observe(secs, variant=vname)
        tracer.counter("n_active", value=row["n_active"])
        state, _ = steps_fns["jnp"](state, x, y, w, cont, nb)
        steps.append(row)
        i += 1

    n = ds.n_cases
    deep = [s for s in steps if s["n_active"] <= n // 4]
    full = [s for s in steps if s["n_active"] > n // 4]
    artifact = {
        "dataset": DATASET,
        "scale": common.SCALES[DATASET],
        "n_cases": n,
        "n_attrs": ds.n_attrs,
        "max_bins": MAX_BINS,
        "backend": jax.default_backend(),
        "frontier_slots": 64,
        "compact_min_bucket": MIN_BUCKET,
        "buckets": list(compaction.bucket_sizes(n, min_bucket=MIN_BUCKET)),
        "steps": steps,
        "metrics": registry.snapshot(),
    }
    out_path = os.environ.get("BENCH_OUT", "BENCH_superstep.json")
    with open(out_path, "w") as f:
        json.dump(artifact, f, indent=1)
    if trace_out:
        tracer.save(trace_out)

    def mean(rows, key):
        return float(np.mean([r[key] for r in rows])) if rows else float("nan")

    rows = []
    for vname in variants:
        rows.append({
            "name": f"superstep/{vname}",
            "us_per_call": f"{mean(steps, f't_{vname}_s') * 1e6:.1f}",
            "n_steps": len(steps),
            "dataset": DATASET,
            "n_cases": n,
        })
    if {"pallas", "pallas_compact"} <= set(variants):
        deep_full = mean(deep, "t_pallas_s")
        deep_compact = mean(deep, "t_pallas_compact_s")
        rows.append({
            "name": "superstep/deep_compaction_speedup",
            "us_per_call": "",
            "n_deep_steps": len(deep),
            "n_shallow_steps": len(full),
            "mean_active_deep": int(mean(deep, "n_active")) if deep else 0,
            "t_deep_full_us": f"{deep_full * 1e6:.1f}",
            "t_deep_compact_us": f"{deep_compact * 1e6:.1f}",
            "speedup": f"{deep_full / deep_compact:.2f}" if deep else "nan",
            "artifact": out_path,
        })
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    common.emit(run())
