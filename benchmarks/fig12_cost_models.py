"""Paper Fig. 12: total execution time per buildAttTest cost model
(|T| < c r^2  vs  alpha < r  vs  |T| < c r log r), NAP, 7 workers."""

from __future__ import annotations

from benchmarks.common import build_with_trace, emit, load_scaled
from repro.core import simulate
from repro.data import datasets


def run() -> list[dict]:
    rows = []
    for name in datasets.TABLE1:
        ds = load_scaled(name)
        _, trace, cm, seq_s = build_with_trace(ds)
        times = {}
        tasks = {}
        for model in ("nsq", "alpha", "nlogn"):
            r = simulate.simulate(trace, n_workers=7, strategy="nap",
                                  policy="ws", cost=cm, cost_model=model)
            times[f"t_{model}"] = round(r.makespan, 4)
            tasks[f"att_{model}"] = r.n_att_tasks
        best = min(("nsq", "alpha", "nlogn"), key=lambda m: times[f"t_{m}"])
        rows.append(dict(name=f"fig12/{name}",
                         us_per_call=f"{seq_s*1e6:.0f}",
                         **times, **tasks, best=best))
    return rows


if __name__ == "__main__":
    emit(run())
