"""Shared benchmark machinery: dataset scaling, timing, CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

from repro.core import c45, frontier, simulate
from repro.core.config import GrowConfig
from repro.data import datasets

# CPU-budget scales for the Table-1 datasets (full sizes are 0.3M..10M cases;
# the farm dynamics we replay depend on the induced tree's task DAG, which
# these scales preserve in shape).  Recorded in every CSV row.
# BENCH_SCALE (env) multiplies all of them (CI smoke: BENCH_SCALE=0.1).
import os as _os

_MULT = float(_os.environ.get("BENCH_SCALE", "1.0"))
SCALES = {
    "census_pums": 0.05 * _MULT,
    "us_census": 0.008 * _MULT,
    "kddcup99": 0.004 * _MULT,
    "forest_cover": 0.03 * _MULT,
    "syd10m9a": 0.004 * _MULT,
}

GROW = GrowConfig(max_nodes=1 << 16, frontier_slots=256)


def timed(fn: Callable, *args, repeats: int = 5, **kw):
    """Paper protocol: 5 runs, drop best+worst, average the rest.

    Blocks on device results — jax dispatch is async, so without
    block_until_ready a jitted build would time only its launch.
    """
    import jax
    times = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        try:
            jax.block_until_ready(out)
        except (TypeError, ValueError):
            pass                       # non-array results (host code)
        times.append(time.perf_counter() - t0)
    times = sorted(times)[1:-1] if len(times) >= 3 else times
    return out, float(np.mean(times))


_DS_CACHE: dict = {}
_TRACE_CACHE: dict = {}


def load_scaled(name: str, seed: int = 0):
    key = (name, seed)
    if key not in _DS_CACHE:
        _DS_CACHE[key] = datasets.load(name, scale=SCALES[name], seed=seed)
    return _DS_CACHE[key]


def build_with_trace(ds, cfg: GrowConfig = GROW):
    """Sequential build (timed) + task trace + calibrated farm cost model.

    Memoised per dataset identity: several figure modules replay the same
    build, and the sequential oracle is the expensive part on one core.
    """
    key = id(ds)
    if key not in _TRACE_CACHE:
        trace: list = []
        tree, seq_seconds = timed(
            lambda: c45.build(ds, cfg, task_trace=trace.clear() or trace),
            repeats=1)
        cm = simulate.calibrate(trace, measured_seq_seconds=seq_seconds)
        _TRACE_CACHE[key] = (tree, trace, cm, seq_seconds)
    return _TRACE_CACHE[key]


def emit(rows: list[dict]) -> None:
    """Print ``name,us_per_call,derived`` CSV rows (benchmark contract)."""
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
