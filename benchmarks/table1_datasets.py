"""Paper Table 1: training sets and induced decision trees.

For each (schema-matched, scaled) dataset: cases, classes, attribute split,
induced tree size/depth from the sequential oracle, and agreement with the
SPMD frontier engine.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import GROW, SCALES, emit, load_scaled, timed
from repro.core import c45, frontier
from repro.core.tree import predict, trees_equal
from repro.data import datasets


def run() -> list[dict]:
    rows = []
    for name, spec in datasets.TABLE1.items():
        ds = load_scaled(name)
        tree, seq_s = timed(lambda: c45.build(ds, GROW), repeats=3)
        ff_tree, ff_s = timed(lambda: frontier.build(ds, GROW), repeats=3)
        acc = float((np.asarray(predict(ff_tree, ds.x, ds.attr_is_cont))
                     == ds.y).mean())
        rows.append(dict(
            name=f"table1/{name}",
            us_per_call=f"{seq_s*1e6:.0f}",
            scale=SCALES[name], cases=ds.n_cases,
            classes=ds.n_classes,
            discrete=int((~ds.attr_is_cont).sum()),
            continuous=int(ds.attr_is_cont.sum()),
            tree_size=tree.size, tree_depth=tree.depth,
            engines_equal=trees_equal(tree, ff_tree),
            frontier_seconds=round(ff_s, 3),
            seq_seconds=round(seq_s, 3),
            train_acc=round(acc, 4),
            paper_tree_size=spec.tree_size, paper_depth=spec.tree_depth,
        ))
    return rows


if __name__ == "__main__":
    emit(run())
