"""Batched forest inference: per-tree loop vs vmap vs pallas traversal.

Times :func:`repro.infer.forest.predict_per_tree` under each implementation
across a grid of forest widths and batch sizes on one trained tree
(replicated to width ``T`` — prediction cost does not depend on tree
diversity, only on node count and depth).  The point of the figure: the
per-tree python loop (``ref``) pays one full descent dispatch per tree,
while the batched paths amortize the whole forest into one launch — at
serving batch sizes (>= 1024 rows) the batched path wins by orders of
magnitude, which is what makes the microbatching front-end
(:mod:`repro.infer.service`) worth its latency floor.

Emits the usual CSV rows *and* writes a ``BENCH_infer.json`` artifact
(path overridable via ``BENCH_OUT``) gated by
``benchmarks/check_regression.py`` against the committed baseline.

Knobs for CI smoke runs (all env vars):

  * ``BENCH_SCALE``        — global dataset scale multiplier (common.py);
  * ``BENCH_BATCH_SIZES``  — comma list of batch sizes (default
    ``64,1024,4096``);
  * ``BENCH_FOREST_WIDTHS``— comma list of forest widths (default
    ``1,8,32``);
  * ``BENCH_VARIANTS``     — comma list of impls to time; ``ref`` always
    runs (it is the per-tree baseline the speedup row divides by).

Off-TPU the pallas kernel runs in interpret mode, so absolute
pallas-vs-vmap times are meaningless there (the JSON records the backend);
the ref-vs-vmap ratio is meaningful everywhere — both are jax on the same
backend, only the launch structure differs.
"""

from __future__ import annotations

import json
import os
import sys

import jax
import numpy as np

if __package__ in (None, ""):      # `python benchmarks/fig_infer.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common
from repro.core import c45
from repro.core.config import GrowConfig
from repro.data import datasets
from repro.infer.forest import Forest, IMPLS, predict_per_tree
from repro.obs.metrics import Registry

DATASET = "syd10m9a"          # QUEST stand-in: 9 attrs, deep tree (Table 1)
MAX_BINS = 32
BATCH_SIZES = tuple(int(v) for v in os.environ.get(
    "BENCH_BATCH_SIZES", "64,1024,4096").split(","))
FOREST_WIDTHS = tuple(int(v) for v in os.environ.get(
    "BENCH_FOREST_WIDTHS", "1,8,32").split(","))


def _variants() -> tuple[str, ...]:
    want = os.environ.get("BENCH_VARIANTS")
    if not want:
        return IMPLS
    keep = {v.strip() for v in want.split(",")} | {"ref"}   # ref = baseline
    unknown = keep - set(IMPLS)
    if unknown:
        raise SystemExit(f"BENCH_VARIANTS: unknown {sorted(unknown)} "
                         f"(have {sorted(IMPLS)})")
    return tuple(v for v in IMPLS if v in keep)


def run() -> list[dict]:
    ds = datasets.load(DATASET, scale=common.SCALES[DATASET], seed=0,
                       max_bins=MAX_BINS)
    tree = c45.build(ds, GrowConfig(max_nodes=1 << 14))
    variants = _variants()

    registry = Registry()
    m_call = registry.histogram(
        "bench_infer_seconds", "timed predict call; variant/width/batch")

    steps: list[dict] = []
    for n_trees in FOREST_WIDTHS:
        forest = Forest.pack([tree] * n_trees)
        for batch in BATCH_SIZES:
            x = np.resize(np.asarray(ds.x), (batch, ds.n_attrs))
            # Grid-point step ids (not positional): a smoke run over a
            # subset of the grid still aligns with the committed baseline.
            row = {"step": f"t{n_trees}_b{batch}",
                   "n_trees": n_trees, "batch": batch}
            for impl in variants:
                _, secs = common.timed(
                    predict_per_tree, forest, x, ds.attr_is_cont,
                    impl=impl, repeats=3)
                row[f"t_{impl}_s"] = secs
                m_call.observe(secs, variant=impl, n_trees=n_trees,
                               batch=batch)
            steps.append(row)

    artifact = {
        "dataset": DATASET,
        "scale": common.SCALES[DATASET],
        "n_cases": ds.n_cases,
        "n_attrs": ds.n_attrs,
        "max_bins": MAX_BINS,
        "backend": jax.default_backend(),
        "tree_nodes": tree.size,
        "tree_depth": tree.depth,
        "batch_sizes": list(BATCH_SIZES),
        "forest_widths": list(FOREST_WIDTHS),
        "steps": steps,
        "metrics": registry.snapshot(),
    }
    out_path = os.environ.get("BENCH_OUT", "BENCH_infer.json")
    with open(out_path, "w") as f:
        json.dump(artifact, f, indent=1)

    def mean(rows, key):
        return float(np.mean([r[key] for r in rows])) if rows else float("nan")

    rows = []
    for impl in variants:
        rows.append({
            "name": f"infer/{impl}",
            "us_per_call": f"{mean(steps, f't_{impl}_s') * 1e6:.1f}",
            "n_points": len(steps),
            "dataset": DATASET,
            "tree_nodes": tree.size,
        })
    # The acceptance ratio: batched vs the per-tree loop at serving sizes.
    serving = [s for s in steps if s["batch"] >= 1024]
    if serving and "vmap" in variants:
        ref_s = mean(serving, "t_ref_s")
        vmap_s = mean(serving, "t_vmap_s")
        row = {
            "name": "infer/batched_speedup",
            "us_per_call": "",
            "n_serving_points": len(serving),
            "t_ref_us": f"{ref_s * 1e6:.1f}",
            "t_vmap_us": f"{vmap_s * 1e6:.1f}",
            "speedup_vmap": f"{ref_s / vmap_s:.2f}",
            "artifact": out_path,
        }
        if "pallas" in variants:
            row["t_pallas_us"] = f"{mean(serving, 't_pallas_s') * 1e6:.1f}"
        rows.append(row)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    common.emit(run())
