"""Paper Figs. 10/11: NAP speedup scalability vs number of attributes and
vs number of cases (SyD10M9A subsets, 7 workers)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import GROW, build_with_trace, emit
from repro.core import simulate
from repro.data import quest


def _with_extra_attrs(n_cases: int, extra: int, seed: int = 0):
    ds = quest.generate(n_cases, function=5, seed=seed)
    if not extra:
        return ds
    rng = np.random.default_rng(seed + 1)
    import dataclasses
    cols = [ds.x]
    edges = list(ds.bin_edges)
    kinds = list(ds.attr_is_cont)
    nb = list(ds.n_bins)
    extra_cols = []
    for _ in range(extra):                      # random uniform attributes
        b = 64
        extra_cols.append(rng.integers(0, b, ds.n_cases).astype(np.int32))
        edges.append(np.arange(b, dtype=np.float64))
        kinds.append(True)
        nb.append(b)
    x = np.concatenate([ds.x] + [c[:, None] for c in extra_cols], axis=1)
    return dataclasses.replace(
        ds, x=x, attr_is_cont=np.asarray(kinds),
        n_bins=np.asarray(nb, np.int32), bin_edges=tuple(edges),
        attr_names=tuple(f"a{i}" for i in range(x.shape[1])))


def run() -> list[dict]:
    rows = []
    # Fig. 10: speedup vs #attributes at fixed cases
    for extra in (0, 9, 27):
        ds = _with_extra_attrs(20_000, extra)
        _, trace, cm, seq_s = build_with_trace(ds)
        r = simulate.simulate(trace, n_workers=7, strategy="nap",
                              policy="ws", cost=cm)
        rows.append(dict(name=f"fig10/attrs{9+extra}",
                         us_per_call=f"{seq_s*1e6:.0f}",
                         speedup7=round(r.speedup, 3)))
    # Fig. 11: speedup vs #cases
    for n in (5_000, 20_000, 80_000):
        ds = quest.generate(n, function=5, seed=1)
        _, trace, cm, seq_s = build_with_trace(ds)
        r = simulate.simulate(trace, n_workers=7, strategy="nap",
                              policy="ws", cost=cm)
        rows.append(dict(name=f"fig11/cases{n}",
                         us_per_call=f"{seq_s*1e6:.0f}",
                         speedup7=round(r.speedup, 3)))
    return rows


if __name__ == "__main__":
    emit(run())
