"""Paper Fig. 13: DRR vs OD vs WS scheduling policies (SyD dataset, NAP)."""

from __future__ import annotations

from benchmarks.common import build_with_trace, emit, load_scaled
from repro.core import simulate

WORKERS = (1, 2, 4, 6, 7, 8)


def run() -> list[dict]:
    ds = load_scaled("syd10m9a")
    _, trace, cm, seq_s = build_with_trace(ds)
    rows = []
    for policy in ("drr", "od", "ws"):
        speedups = {}
        for w in WORKERS:
            r = simulate.simulate(trace, n_workers=w, strategy="nap",
                                  policy=policy, cost=cm)
            speedups[f"w{w}"] = round(r.speedup, 3)
        rows.append(dict(name=f"fig13/{policy}",
                         us_per_call=f"{seq_s*1e6:.0f}", **speedups))
    return rows


if __name__ == "__main__":
    emit(run())
