"""Data substrate: QUEST synthetic generator, Table-1 dataset stand-ins,
and the sharded/resumable LM token pipeline."""
