"""QUEST/Agrawal synthetic classification generator (paper Sect. 5).

The paper's largest dataset, *SyD10M9A*, is "synthetically generated using
function 5 of the QUEST data generator" — the classic Agrawal et al.
generator (An Interval Classifier for Database Mining Applications, VLDB'92)
with 9 predictive attributes (6 continuous, 3 discrete) and 2 classes,
exactly Table 1's schema.

We implement the attribute model and classification functions 1–5 following
the widely-used MOA ``AgrawalGenerator`` formulation (the original IBM QUEST
code is no longer distributed).  Function 5 labels by age-banded salary and
loan intervals.

Attributes (order matters — it is Table 1's 6 continuous + 3 discrete):

  salary      continuous  U[20k, 150k]
  commission  continuous  0 if salary >= 75k else U[10k, 75k]
  age         continuous  U[20, 80]
  hvalue      continuous  U[50k, 150k] * zipcode-dependent factor
  hyears      continuous  U[1, 30]
  loan        continuous  U[0, 500k]
  elevel      discrete    {0..4}
  car         discrete    {0..19}
  zipcode     discrete    {0..8}
"""

from __future__ import annotations

import numpy as np

from repro.core.binning import BinnedDataset, fit

ATTR_NAMES = ("salary", "commission", "age", "hvalue", "hyears", "loan",
              "elevel", "car", "zipcode")
ATTR_IS_CONT = (True, True, True, True, True, True, False, False, False)


def _raw_attributes(n: int, rng: np.random.Generator) -> dict[str, np.ndarray]:
    salary = rng.uniform(20_000, 150_000, n)
    commission = np.where(salary >= 75_000, 0.0, rng.uniform(10_000, 75_000, n))
    age = rng.uniform(20, 80, n)
    elevel = rng.integers(0, 5, n)
    car = rng.integers(0, 20, n)
    zipcode = rng.integers(0, 9, n)
    hvalue = rng.uniform(50_000, 150_000, n) * (zipcode + 1) * 0.5
    hyears = rng.uniform(1, 30, n)
    loan = rng.uniform(0, 500_000, n)
    return dict(salary=salary, commission=commission, age=age, hvalue=hvalue,
                hyears=hyears, loan=loan, elevel=elevel, car=car,
                zipcode=zipcode)


def _classify(fn: int, a: dict[str, np.ndarray]) -> np.ndarray:
    """Group A = class 0, Group B = class 1 (MOA functions 1-5)."""
    age, salary, loan, elevel = a["age"], a["salary"], a["loan"], a["elevel"]
    if fn == 1:
        group_a = (age < 40) | (age >= 60)
    elif fn == 2:
        group_a = np.select(
            [age < 40, age < 60],
            [(50_000 <= salary) & (salary <= 100_000),
             (75_000 <= salary) & (salary <= 125_000)],
            (25_000 <= salary) & (salary <= 75_000))
    elif fn == 3:
        group_a = np.select(
            [age < 40, age < 60],
            [np.isin(elevel, (0, 1)), np.isin(elevel, (1, 2, 3))],
            np.isin(elevel, (2, 3, 4)))
    elif fn == 4:
        group_a = np.select(
            [age < 40, age < 60],
            [np.where(np.isin(elevel, (0, 1)),
                      (25_000 <= salary) & (salary <= 75_000),
                      (50_000 <= salary) & (salary <= 100_000)),
             np.where(np.isin(elevel, (1, 2, 3)),
                      (50_000 <= salary) & (salary <= 100_000),
                      (75_000 <= salary) & (salary <= 125_000))],
            np.where(np.isin(elevel, (2, 3, 4)),
                     (50_000 <= salary) & (salary <= 100_000),
                     (25_000 <= salary) & (salary <= 75_000)))
    elif fn == 5:
        group_a = np.select(
            [age < 40, age < 60],
            [(50_000 <= salary) & (salary <= 100_000)
             & (100_000 <= loan) & (loan <= 300_000),
             (75_000 <= salary) & (salary <= 125_000)
             & (200_000 <= loan) & (loan <= 400_000)],
            (25_000 <= salary) & (salary <= 75_000)
            & (300_000 <= loan) & (loan <= 500_000))
    else:
        raise ValueError(f"function {fn} not implemented (1..5)")
    return np.where(group_a, 0, 1).astype(np.int32)


def generate(n: int, *, function: int = 5, seed: int = 0,
             perturbation: float = 0.05, max_bins: int = 256,
             ) -> BinnedDataset:
    """Generate an Agrawal/QUEST dataset in rank space.

    ``perturbation`` is QUEST's label-noise knob: that fraction of labels is
    flipped uniformly (keeps induced trees realistic rather than exact).
    """
    rng = np.random.default_rng(seed)
    attrs = _raw_attributes(n, rng)
    y = _classify(function, attrs)
    if perturbation > 0:
        flip = rng.random(n) < perturbation
        y = np.where(flip, 1 - y, y)
    columns = [attrs[name] for name in ATTR_NAMES]
    return fit(columns, y, attr_is_cont=ATTR_IS_CONT, n_classes=2,
               max_bins=max_bins, attr_names=ATTR_NAMES)


def syd(n: int = 10_000_000, *, seed: int = 0, max_bins: int = 256,
        ) -> BinnedDataset:
    """SyD10M9A (paper Table 1) — pass a smaller ``n`` for scaled runs."""
    return generate(n, function=5, seed=seed, max_bins=max_bins)
