"""Sharded, deterministic, resumable LM token pipeline.

Production data loading for the assigned-architecture fleet.  Design points
that matter at 1000+ nodes:

  * **Determinism** — batch ``i`` is a pure function of (seed, step), so any
    host can regenerate any shard at any time; restart-after-failure never
    replays or skips data.
  * **Shard-by-construction** — each host materialises only its
    ``(host_index, num_hosts)`` slice of the global batch; there is no
    central dispatcher to fail.
  * **Resumability** — the loader state is a single integer (``step``);
    checkpoints persist it and ``seek(step)`` is O(1).
  * **Prefetch overlap** — a one-slot software pipeline hides host->device
    transfer behind the previous step's compute (double buffering).

Offline container: the token source is a seeded PRNG stream shaped like a
tokenized corpus (Zipf-ish marginals, document boundaries with EOS); swap
``TokenSource`` for a real corpus reader in deployment — every other layer
is unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 512


class TokenSource:
    """Seeded synthetic corpus: batch index -> token block (pure function)."""

    def __init__(self, cfg: LoaderConfig):
        self.cfg = cfg

    def block(self, step: int, row_lo: int, row_hi: int) -> np.ndarray:
        cfg = self.cfg
        rows = []
        for r in range(row_lo, row_hi):
            rng = np.random.default_rng(
                (cfg.seed, step, r))            # content-addressed by (step,row)
            # Zipf-ish marginals over the vocab, cheap to sample:
            z = rng.zipf(1.3, size=cfg.seq_len + 1).astype(np.int64)
            toks = (z - 1) % (cfg.vocab_size - 1) + 1
            # document boundaries
            n_eos = max(1, (cfg.seq_len + 1) // cfg.mean_doc_len)
            pos = rng.integers(0, cfg.seq_len + 1, size=n_eos)
            toks[pos] = cfg.eos_id
            rows.append(toks)
        return np.stack(rows).astype(np.int32)


@dataclasses.dataclass
class LoaderState:
    step: int = 0


class ShardedLoader:
    """Per-host view of the global batch stream (data-parallel sharding)."""

    def __init__(self, cfg: LoaderConfig, *, host_index: int = 0,
                 num_hosts: int = 1, source: TokenSource | None = None):
        if cfg.global_batch % num_hosts:
            raise ValueError("global batch must divide evenly across hosts")
        self.cfg = cfg
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.rows_per_host = cfg.global_batch // num_hosts
        self.source = source or TokenSource(cfg)
        self.state = LoaderState()

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        return dict(step=self.state.step)

    def load_state_dict(self, d: dict) -> None:
        self.state.step = int(d["step"])

    def seek(self, step: int) -> None:
        self.state.step = int(step)

    # -- iteration -------------------------------------------------------------
    def next_batch(self) -> dict[str, np.ndarray]:
        lo = self.host_index * self.rows_per_host
        block = self.source.block(self.state.step, lo,
                                  lo + self.rows_per_host)
        self.state.step += 1
        return dict(tokens=block[:, :-1], labels=block[:, 1:])

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def prefetched(self, device=None) -> Iterator[dict]:
        """Double-buffered iterator: next host batch overlaps device compute."""
        device = device or jax.devices()[0]
        it = iter(self)
        nxt = jax.device_put(next(it), device)
        while True:
            cur, nxt = nxt, None
            host = next(it)
            nxt = jax.device_put(host, device)   # enqueue before yielding
            yield cur
