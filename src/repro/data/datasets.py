"""Schema-matched stand-ins for the paper's Table 1 training sets.

The four UCI datasets are not redistributable inside this offline container,
so each is replaced by a synthetic dataset with the *same schema* (cases,
classes, discrete/continuous attribute counts) and a learnable structure: a
random ground-truth decision tree over the schema labels the cases, plus
label noise — giving induced trees of realistic size/depth for the
scheduling benchmarks (what the paper's figures measure is farm dynamics
over the task DAG, which depends on the tree shape, not on UCI semantics).

``load(name, scale=...)`` subsamples the case count for CPU-budget runs;
benchmarks record the scale they used.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.binning import BinnedDataset, fit
from repro.data import quest


@dataclasses.dataclass(frozen=True)
class TableOneSpec:
    name: str
    n_cases: int
    n_classes: int
    n_discrete: int
    n_continuous: int
    tree_size: int      # as reported in paper Table 1 (for reference)
    tree_depth: int


TABLE1: dict[str, TableOneSpec] = {
    "census_pums": TableOneSpec("Census PUMS", 299_285, 2, 33, 7,
                                122_306, 31),
    "us_census": TableOneSpec("U.S. Census", 2_458_285, 5, 67, 0,
                              125_621, 44),
    "kddcup99": TableOneSpec("KDD Cup 99", 4_898_431, 23, 7, 34, 2_810, 29),
    "forest_cover": TableOneSpec("Forest Cover", 581_012, 7, 44, 10,
                                 41_775, 62),
    "syd10m9a": TableOneSpec("SyD10M9A", 10_000_000, 2, 3, 6, 169_108, 22),
}


def _random_tree_labels(x_cols: list[np.ndarray], is_cont: list[bool],
                        n_classes: int, rng: np.random.Generator,
                        depth: int = 12, noise: float = 0.08) -> np.ndarray:
    """Label cases by a random ground-truth tree over the given columns."""
    n = len(x_cols[0])
    y = np.zeros(n, np.int32)

    def grow(idx: np.ndarray, d: int) -> None:
        if d == 0 or len(idx) < 64:
            y[idx] = rng.integers(0, n_classes)
            return
        a = int(rng.integers(0, len(x_cols)))
        col = x_cols[a][idx]
        if is_cont[a]:
            thr = np.quantile(col, rng.uniform(0.25, 0.75))
            left = col <= thr
        else:
            vals = np.unique(col)
            pick = rng.choice(vals, size=max(1, len(vals) // 2),
                              replace=False)
            left = np.isin(col, pick)
        if left.all() or not left.any():
            y[idx] = rng.integers(0, n_classes)
            return
        grow(idx[left], d - 1)
        grow(idx[~left], d - 1)

    grow(np.arange(n), depth)
    flip = rng.random(n) < noise
    y[flip] = rng.integers(0, n_classes, int(flip.sum()))
    return y


def load(name: str, *, scale: float = 1.0, seed: int = 0,
         max_bins: int = 128) -> BinnedDataset:
    """Materialise a Table-1 stand-in at ``scale`` of its original size."""
    spec = TABLE1[name]
    n = max(256, int(spec.n_cases * scale))
    if name == "syd10m9a":
        return quest.generate(n, function=5, seed=seed, max_bins=max_bins)

    import zlib
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (1 << 16))
    cols: list[np.ndarray] = []
    kinds: list[bool] = []
    for _ in range(spec.n_continuous):
        loc, sc = rng.uniform(-5, 5), rng.uniform(0.5, 3.0)
        cols.append(rng.normal(loc, sc, n))
        kinds.append(True)
    for _ in range(spec.n_discrete):
        h = int(rng.integers(2, 12))
        cols.append(rng.integers(0, h, n))
        kinds.append(False)
    y = _random_tree_labels(cols, kinds, spec.n_classes, rng)
    return fit(cols, y, attr_is_cont=kinds, n_classes=spec.n_classes,
               max_bins=max_bins)
