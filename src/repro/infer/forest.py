"""Packed forests: stacked node arrays + batched ensemble prediction.

A :class:`Forest` packs one or many :class:`~repro.core.tree.Tree`\\ s into a
padded structure-of-arrays at a common capacity: every node array gains a
leading tree axis, so the whole ensemble is one pytree of ``(T, M, ...)``
tensors.  That shape is what makes inference embarrassingly data-parallel
(the Bayesian-trees line of related work treats prediction over many trees
as *the* parallel unit): batched prediction is a ``vmap`` of the shared
descend step over the tree axis, or the Pallas traversal kernel
(:mod:`repro.kernels.tree_infer`) when the one-hot MXU formulation wins.

The heaviest-child table is precomputed at pack time
(:func:`repro.core.tree.heavy_child_table`), so unknown-value routing is
exact for any split arity in every implementation.

Implementations (all oracle-equal to per-tree :func:`repro.core.tree.predict`):

  ``ref``    — per-tree Python loop over ``tree.predict`` (the oracle);
  ``vmap``   — one jitted vmap over the stacked arrays;
  ``pallas`` — the level-synchronous traversal kernel via
               :func:`repro.kernels.ops.forest_predict`.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import Tree, descend_once, heavy_child_table
from repro.kernels import tree_infer

IMPLS = ("ref", "vmap", "pallas")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Forest:
    """T trees stacked at common capacity M (C classes).

    Same per-node fields as :class:`~repro.core.tree.Tree` plus the
    precomputed heavy-child table and a per-tree vote weight.  ``n_nodes``
    is the live prefix per tree; padding past it is leaf-shaped (nchild 0).
    """

    node_attr: jnp.ndarray       # int32 (T, M)
    node_split_bin: jnp.ndarray  # int32 (T, M)
    node_child0: jnp.ndarray     # int32 (T, M)
    node_nchild: jnp.ndarray     # int32 (T, M)
    node_class: jnp.ndarray      # int32 (T, M)
    node_freq: jnp.ndarray       # f32   (T, M, C)
    node_depth: jnp.ndarray      # int32 (T, M)
    node_heavy: jnp.ndarray      # int32 (T, M) sibling rank of heaviest child
    n_nodes: jnp.ndarray         # int32 (T,)
    tree_weight: jnp.ndarray     # f32   (T,) ensemble vote weight

    # ------------------------------------------------------------ properties
    @property
    def n_trees(self) -> int:
        return int(self.node_attr.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.node_attr.shape[1])

    @property
    def n_classes(self) -> int:
        return int(self.node_freq.shape[-1])

    @property
    def n_levels(self) -> int:
        """Descent trip count: 1 + the deepest live node over all trees."""
        nd = np.asarray(self.node_depth)
        nn = np.asarray(self.n_nodes)
        deepest = 0
        for t in range(nd.shape[0]):
            if nn[t]:
                deepest = max(deepest, int(nd[t, : nn[t]].max()))
        return deepest + 1

    # --------------------------------------------------------------- packing
    @staticmethod
    def pack(trees: list[Tree], *, weights=None,
             capacity: int | None = None) -> "Forest":
        """Stack trees' live prefixes at a common (padded) capacity."""
        if not trees:
            raise ValueError("Forest.pack: need at least one tree")
        host = [t.to_numpy() for t in trees]
        n_classes = {t.node_freq.shape[-1] for t in host}
        if len(n_classes) != 1:
            raise ValueError(f"trees disagree on n_classes: {n_classes}")
        c = n_classes.pop()
        sizes = [int(t.n_nodes) for t in host]
        m = max(max(sizes, default=1), 1)
        if capacity is not None:
            if capacity < m:
                raise ValueError(f"capacity {capacity} < largest tree {m}")
            m = capacity
        t_dim = len(host)

        def stack(field, fill, dtype, extra=()):
            out = np.full((t_dim, m, *extra), fill, dtype)
            for i, (tr, n) in enumerate(zip(host, sizes)):
                out[i, :n] = getattr(tr, field)[:n]
            return jnp.asarray(out)

        heavy = np.zeros((t_dim, m), np.int32)
        child0 = stack("node_child0", 0, np.int32)
        nchild = stack("node_nchild", 0, np.int32)
        freq = stack("node_freq", 0.0, np.float32, (c,))
        for i in range(t_dim):
            heavy[i] = np.asarray(
                heavy_child_table(child0[i], nchild[i], freq[i]))
        w = (np.ones(t_dim, np.float32) if weights is None
             else np.asarray(weights, np.float32))
        if w.shape != (t_dim,):
            raise ValueError(f"weights shape {w.shape} != ({t_dim},)")
        return Forest(
            node_attr=stack("node_attr", -1, np.int32),
            node_split_bin=stack("node_split_bin", -1, np.int32),
            node_child0=child0,
            node_nchild=nchild,
            node_class=stack("node_class", 0, np.int32),
            node_freq=freq,
            node_depth=stack("node_depth", 0, np.int32),
            node_heavy=jnp.asarray(heavy),
            n_nodes=jnp.asarray(sizes, jnp.int32),
            tree_weight=jnp.asarray(w),
        )

    def tree(self, i: int) -> Tree:
        """Unpack tree ``i`` (capacity = the forest's common capacity)."""
        return Tree(
            node_attr=self.node_attr[i],
            node_split_bin=self.node_split_bin[i],
            node_child0=self.node_child0[i],
            node_nchild=self.node_nchild[i],
            node_class=self.node_class[i],
            node_freq=self.node_freq[i],
            node_depth=self.node_depth[i],
            n_nodes=self.n_nodes[i],
        )

    def node_table(self) -> jnp.ndarray:
        """(T, M, NODE_COLS) int32 table for the Pallas traversal kernel."""
        cols = jnp.stack(
            [self.node_attr, self.node_split_bin, self.node_child0,
             self.node_nchild, self.node_heavy, self.node_class],
            axis=-1).astype(jnp.int32)
        pad = tree_infer.NODE_COLS - cols.shape[-1]
        return jnp.pad(cols, ((0, 0), (0, 0), (0, pad)))


# ----------------------------------------------------------------- prediction

@functools.partial(jax.jit, static_argnames=("max_depth",))
def _predict_vmap(forest: Forest, x_bins: jnp.ndarray,
                  attr_is_cont: jnp.ndarray, *, max_depth: int
                  ) -> jnp.ndarray:
    def one_tree(attr, sbin, child0, nchild, cls, heavy):
        def body(_, node):
            return descend_once(attr_is_cont, node, x_bins,
                                node_attr=attr, node_split_bin=sbin,
                                node_child0=child0, node_nchild=nchild,
                                heavy=heavy)
        node = jnp.zeros((x_bins.shape[0],), jnp.int32)
        node = jax.lax.fori_loop(0, max_depth, body, node)
        return cls[node]

    return jax.vmap(one_tree)(
        forest.node_attr, forest.node_split_bin, forest.node_child0,
        forest.node_nchild, forest.node_class, forest.node_heavy)


def predict_per_tree(forest: Forest, x_bins, attr_is_cont, *,
                     impl: str = "vmap", max_depth: int | None = None,
                     block_n: int | None = None,
                     interpret: bool | None = None) -> jnp.ndarray:
    """(T, N) leaf classes, one row per packed tree."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r} (one of {IMPLS})")
    x_bins = jnp.asarray(x_bins, jnp.int32)
    attr_is_cont = jnp.asarray(attr_is_cont, bool)
    if max_depth is None:
        max_depth = forest.n_levels
    if impl == "ref":
        from repro.core.tree import predict as tree_predict
        return jnp.stack([
            tree_predict(forest.tree(i), x_bins, attr_is_cont,
                         max_depth=max_depth)
            for i in range(forest.n_trees)])
    if impl == "vmap":
        return _predict_vmap(forest, x_bins, attr_is_cont,
                             max_depth=max_depth)
    from repro.kernels import ops
    return ops.forest_predict(forest.node_table(), x_bins, attr_is_cont,
                              max_depth=max_depth, block_n=block_n,
                              interpret=interpret)


def vote(per_tree: jnp.ndarray, tree_weight: jnp.ndarray, *,
         n_classes: int) -> jnp.ndarray:
    """Aggregate (T, N) per-tree classes into (N,) by weighted vote.

    Majority vote is the ``tree_weight == 1`` special case; ties break to
    the lowest class id (argmax convention, deterministic).
    """
    onehot = jax.nn.one_hot(per_tree, n_classes, dtype=jnp.float32)  # (T,N,C)
    tally = jnp.einsum("tnc,t->nc", onehot, tree_weight)
    return jnp.argmax(tally, axis=-1).astype(jnp.int32)


def predict(forest: Forest, x_bins, attr_is_cont, *, impl: str = "vmap",
            weighted: bool = True, max_depth: int | None = None,
            block_n: int | None = None,
            interpret: bool | None = None) -> jnp.ndarray:
    """(N,) ensemble prediction: per-tree descent + weighted majority vote.

    ``weighted=False`` ignores ``tree_weight`` (plain majority).  A 1-tree
    forest returns exactly that tree's predictions for every ``impl``.
    """
    per_tree = predict_per_tree(forest, x_bins, attr_is_cont, impl=impl,
                                max_depth=max_depth, block_n=block_n,
                                interpret=interpret)
    w = forest.tree_weight if weighted \
        else jnp.ones((forest.n_trees,), jnp.float32)
    return vote(per_tree, w, n_classes=forest.n_classes)
