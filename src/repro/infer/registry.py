"""Versioned on-disk model registry with atomic publish and hot-swap.

Layout (one directory per model name, one per published version)::

    <root>/<name>/
        v00000001/
            model.npz        # every Forest array, np.savez
            manifest.json    # version, schema, per-array crc32, metadata
        v00000002/...
        tmp.<ver>.<pid>.<seq>/   # in-flight publish (crashed ones are GC'd)

Publishing follows the same ``tmp.* + os.replace`` discipline as
:mod:`repro.train.checkpoint`: every file lands in a ``tmp.*`` staging
directory and one atomic rename makes the version visible — a crash between
tmp-write and rename leaves :func:`latest_valid` serving the prior version,
and the torn staging directory is garbage-collected once it is old enough
to be presumed abandoned.

:class:`ModelHandle` is the serving-side view: it pins the newest valid
version, ``refresh()`` hot-swaps to later publishes, and canary / shadow
routing splits traffic between the pinned stable version and a candidate by
a deterministic per-uid hash fraction (same uid -> same arm, every process).
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import shutil
import zipfile
import zlib
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.tree import Tree
from repro.infer.forest import Forest
from repro.train.checkpoint import TMP_GC_AGE, gc_stale_tmp

_MANIFEST = "manifest.json"
_MODEL = "model.npz"
_PUB_SEQ = itertools.count()
SCHEMA_VERSION = 1

#: Forest fields serialized into ``model.npz`` (order is the npz key order).
_FIELDS = tuple(f.name for f in dataclasses.fields(Forest))


def _version_dir(version: int) -> str:
    return f"v{version:08d}"


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save_forest(path: str, forest: Forest, *, version: int,
                metadata: dict | None = None) -> None:
    """Write ``model.npz`` + ``manifest.json`` into an existing directory."""
    arrays = {f: np.asarray(getattr(forest, f)) for f in _FIELDS}
    np.savez(os.path.join(path, _MODEL), **arrays)
    manifest = {
        "schema": SCHEMA_VERSION,
        "version": version,
        "n_trees": forest.n_trees,
        "capacity": forest.capacity,
        "n_classes": forest.n_classes,
        "n_levels": forest.n_levels,
        "metadata": metadata or {},
        "arrays": {f: {"shape": list(a.shape), "dtype": str(a.dtype),
                       "crc32": _crc(a)}
                   for f, a in arrays.items()},
    }
    with open(os.path.join(path, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)


def load(path: str) -> tuple[Forest, dict]:
    """Load a published version directory -> (Forest, manifest)."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, _MODEL)) as z:
        forest = Forest(**{f: jnp.asarray(z[f]) for f in _FIELDS})
    return forest, manifest


def verify(path: str) -> bool:
    """True iff the version's arrays match the manifest checksums."""
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, _MODEL)) as z:
            for field, meta in manifest["arrays"].items():
                arr = z[field]
                if list(arr.shape) != meta["shape"] \
                        or str(arr.dtype) != meta["dtype"] \
                        or _crc(arr) != meta["crc32"]:
                    return False
        return set(manifest["arrays"]) == set(_FIELDS)
    except (OSError, ValueError, KeyError, TypeError, zipfile.BadZipFile):
        return False


_RETIRED_PREFIX = "retired."


def list_versions(root: str, name: str) -> list[str]:
    """Published version directories, oldest first (validity not checked)."""
    d = os.path.join(root, name)
    if not os.path.isdir(d):
        return []
    return [os.path.join(d, v) for v in sorted(os.listdir(d))
            if v.startswith("v") and v[1:].isdigit()]


def list_retired(root: str, name: str) -> list[str]:
    """Rolled-back version directories, oldest first."""
    d = os.path.join(root, name)
    if not os.path.isdir(d):
        return []
    return [os.path.join(d, v) for v in sorted(os.listdir(d))
            if v.startswith(_RETIRED_PREFIX)
            and v[len(_RETIRED_PREFIX) + 1:].isdigit()]


def _next_version(root: str, name: str) -> int:
    """Next version number, never reusing one a retired dir ever held —
    a re-publish after :func:`rollback` must not collide with the path a
    serving handle may still have pinned."""
    nums = [int(os.path.basename(p)[1:]) for p in list_versions(root, name)]
    nums += [int(os.path.basename(p)[len(_RETIRED_PREFIX) + 1:])
             for p in list_retired(root, name)]
    return 1 + (max(nums) if nums else 0)


def latest_valid(root: str, name: str, *,
                 gc_tmp_age: float | None = TMP_GC_AGE) -> str | None:
    """Newest version passing checksum verification (same contract as
    ``train.checkpoint.latest_valid``, including stale-``tmp.*`` GC)."""
    d = os.path.join(root, name)
    if not os.path.isdir(d):
        return None
    if gc_tmp_age is not None:
        gc_stale_tmp(d, max_age=gc_tmp_age)
    for path in reversed(list_versions(root, name)):
        if verify(path):
            return path
    return None


def publish(root: str, name: str, model: Forest | Tree, *,
            metadata: dict | None = None,
            weights=None, keep_last: int | None = None) -> str:
    """Atomically publish the next version of ``name``; returns its path.

    Accepts a single :class:`Tree` (packed as a 1-tree forest) or a
    :class:`Forest`.  The version directory appears with one ``os.replace``
    — readers never observe a partially-written model.

    ``keep_last=N`` runs retention GC after the publish: only the N newest
    version directories (and the N newest retired ones) survive, so version
    dirs no longer accumulate forever.  Pick N larger than the rollback /
    canary depth you need — a pinned :class:`ModelHandle` whose version is
    GC'd keeps serving from memory but cannot re-load it.
    """
    if isinstance(model, Tree):
        model = Forest.pack([model], weights=weights)
    d = os.path.join(root, name)
    os.makedirs(d, exist_ok=True)
    version = _next_version(root, name)
    final = os.path.join(d, _version_dir(version))
    tmp = os.path.join(d, f"tmp.{version}.{os.getpid()}.{next(_PUB_SEQ)}")
    os.makedirs(tmp)
    save_forest(tmp, model, version=version, metadata=metadata)
    os.replace(tmp, final)
    if keep_last is not None:
        gc_versions(root, name, keep_last=keep_last)
    return final


def gc_versions(root: str, name: str, *, keep_last: int) -> list[str]:
    """Delete all but the ``keep_last`` newest published (and retired)
    version directories; returns the removed paths, oldest first."""
    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    removed = []
    for paths in (list_versions(root, name), list_retired(root, name)):
        for p in paths[:-keep_last] if keep_last < len(paths) else []:
            shutil.rmtree(p, ignore_errors=True)
            removed.append(p)
    return removed


def rollback(root: str, name: str) -> str | None:
    """Retire the newest version so :func:`latest_valid` re-points below it.

    The newest version directory is renamed to ``retired.v*`` (one atomic
    ``os.replace`` — readers never observe a half-retired version), which
    removes it from :func:`list_versions` / :func:`latest_valid` without
    destroying the bits.  Returns the new ``latest_valid`` path, or ``None``
    when no published version remains.  A later :func:`publish` never reuses
    the retired number.  Raises :class:`FileNotFoundError` when there is no
    version to retire.
    """
    versions = list_versions(root, name)
    if not versions:
        raise FileNotFoundError(
            f"no published version of {name!r} under {root!r} to roll back")
    newest = versions[-1]
    d = os.path.dirname(newest)
    os.replace(newest,
               os.path.join(d, _RETIRED_PREFIX + os.path.basename(newest)))
    return latest_valid(root, name)


def manifest_of(path: str) -> dict:
    with open(os.path.join(path, _MANIFEST)) as f:
        return json.load(f)


# ------------------------------------------------------------------ serving

#: Hash-space resolution for canary fractions (1e-4 granularity).
_ROUTE_BUCKETS = 10_000


def route_bucket(uid: int) -> int:
    """Deterministic per-uid bucket in [0, _ROUTE_BUCKETS)."""
    return zlib.crc32(str(int(uid)).encode()) % _ROUTE_BUCKETS


@dataclasses.dataclass
class _Loaded:
    path: str
    forest: Forest
    manifest: dict


class ModelHandle:
    """Hot-swappable serving view of one registry entry.

    * ``refresh()`` re-resolves :func:`latest_valid` and swaps the stable
      model in place when a newer valid version landed — the serving loop
      never restarts.
    * ``set_canary(path, fraction)`` routes ``fraction`` of uids (by
      deterministic hash) to a candidate version; ``clear_canary()``,
      ``promote_canary()`` end the experiment.
    * ``shadow=True`` makes the canary a *shadow*: every request is served
      by stable, and the service mirrors the batch to the canary model for
      comparison only (no user-visible traffic shift).
    """

    def __init__(self, root: str, name: str, *,
                 canary_fraction: float = 0.0, shadow: bool = False):
        self.root = root
        self.name = name
        self.canary_fraction = float(canary_fraction)
        self.shadow = shadow
        self._stable: _Loaded | None = None
        self._canary: _Loaded | None = None
        self.refresh()
        if self._stable is None:
            raise FileNotFoundError(
                f"no valid published version of {name!r} under {root!r}")

    # ------------------------------------------------------------- versions
    def refresh(self) -> bool:
        """Swap to the newest valid version; True when a swap happened."""
        path = latest_valid(self.root, self.name)
        if path is None or (self._stable and self._stable.path == path):
            return False
        forest, manifest = load(path)
        self._stable = _Loaded(path, forest, manifest)
        return True

    @property
    def stable_path(self) -> str:
        return self._stable.path

    @property
    def stable(self) -> Forest:
        return self._stable.forest

    @property
    def canary(self) -> Forest | None:
        return self._canary.forest if self._canary else None

    @property
    def canary_path(self) -> str | None:
        return self._canary.path if self._canary else None

    # --------------------------------------------------------------- canary
    def set_canary(self, path: str, fraction: float | None = None,
                   *, shadow: bool | None = None) -> None:
        if not verify(path):
            raise ValueError(f"canary candidate fails verification: {path}")
        forest, manifest = load(path)
        self._canary = _Loaded(path, forest, manifest)
        if fraction is not None:
            self.canary_fraction = float(fraction)
        if shadow is not None:
            self.shadow = shadow

    def clear_canary(self) -> None:
        self._canary = None
        self.canary_fraction = 0.0

    def promote_canary(self) -> None:
        """Make the canary the stable model (in-memory hot swap)."""
        if self._canary is None:
            raise ValueError("no canary to promote")
        self._stable, self._canary = self._canary, None
        self.canary_fraction = 0.0

    # -------------------------------------------------------------- routing
    def route(self, uid: int) -> str:
        """``"stable" | "canary"`` arm for this uid (shadow never shifts)."""
        if self._canary is None or self.shadow:
            return "stable"
        frac = min(max(self.canary_fraction, 0.0), 1.0)
        in_canary = route_bucket(uid) < int(frac * _ROUTE_BUCKETS)
        return "canary" if in_canary else "stable"

    def model_for(self, uid: int) -> Forest:
        return self.canary if self.route(uid) == "canary" else self.stable

    def shadow_model(self) -> Forest | None:
        """The mirror target, when shadow mode is armed."""
        return self.canary if (self.shadow and self._canary) else None
