"""Batched tree-inference serving subsystem.

Three layers (README "Inference serving"):

  * :mod:`repro.infer.forest`   — pack :class:`~repro.core.tree.Tree`\\ s
    into a padded structure-of-arrays :class:`Forest`; batched prediction
    via vmap or the Pallas traversal kernel; ensemble vote aggregation.
  * :mod:`repro.infer.registry` — versioned on-disk model registry with
    atomic publish, checksum verification and a hot-swap
    :class:`ModelHandle` (canary / shadow routing).
  * :mod:`repro.infer.service`  — microbatching predict front-end over a
    fleet of replicas, scheduled by the paper's farm policies.
"""

from repro.infer.forest import Forest, predict, predict_per_tree  # noqa: F401
from repro.infer.registry import ModelHandle                      # noqa: F401
from repro.infer.service import (                                 # noqa: F401
    BatchPredictService, InferReplica, PredictRequest)
