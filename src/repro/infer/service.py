"""Microbatching predict service: coalesce, schedule, survive replicas.

Single-row predict requests are individually tiny — the batched kernels
(:mod:`repro.infer.forest`) only pay off when N is large.  This front-end
closes the gap with **microbatching**: requests queue per routing arm
(stable / canary) and a batch closes when it reaches ``max_batch`` rows or
its oldest request has waited ``max_wait_ticks`` engine ticks, trading a
bounded latency floor for kernel-efficient batch shapes.

Closed batches are *tasks on a farm of replicas*, exactly the paper's
emitter/worker shape reused a third time (tree build, LM serving, now
inference): the dispatcher picks a replica per batch with
:func:`repro.core.scheduler.make_policy` (``drr | od | ws | health_ws``,
task weight = batch rows), and replica faults follow the
:mod:`repro.serve.engine` failover contract — a replica whose ``admit`` or
``tick`` raises is evicted (masked as a zero-capacity view so stateful
policies keep addressing physical indices), its queued requests are
re-admitted under a bounded per-request requeue budget, and
``run_until_drained`` ends every submitted request as exactly one
:class:`PredictResult` or one :class:`PredictFailure`.

Canary / shadow: a :class:`~repro.infer.registry.ModelHandle` routes each
uid deterministically to an arm; shadow mode mirrors every dispatched batch
to the candidate model and only records agreement metrics.

Everything is instrumented through :mod:`repro.obs`: queue-wait and
batch-size histograms, per-replica busy counters, per-request async spans.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import numpy as np

from repro.core.scheduler import Policy, QueueState, make_policy
from repro.infer.forest import Forest, predict as forest_predict
from repro.infer.registry import ModelHandle
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class PredictRequest:
    uid: int
    x_row: np.ndarray            # (A,) binned case; -1 = unknown

    @property
    def weight(self) -> float:
        return 1.0


@dataclasses.dataclass
class PredictResult:
    uid: int
    label: int
    replica: int
    batch_size: int              # rows in the batch that served this uid
    arm: str = "stable"


@dataclasses.dataclass
class PredictFailure:
    """Explicit terminal record for a request that was never served."""

    uid: int
    reason: str                  # replica_dead | requeue_exhausted |
                                 # no_replicas | max_ticks
    detail: str = ""


@dataclasses.dataclass
class _Batch:
    arm: str
    requests: list

    @property
    def weight(self) -> float:
        return float(len(self.requests))


def _predict_fn(forest: Forest, attr_is_cont, *, impl: str,
                weighted: bool = True) -> Callable[[np.ndarray], np.ndarray]:
    cont = np.asarray(attr_is_cont, bool)

    def fn(x_rows: np.ndarray) -> np.ndarray:
        return np.asarray(forest_predict(forest, x_rows, cont, impl=impl,
                                         weighted=weighted))
    return fn


class InferReplica:
    """One inference worker: a bounded queue of batches + per-arm models.

    ``models`` maps routing arm -> batch predict fn ``(n, A) -> (n,)``;
    ``shadow_fn`` (optional) mirrors each batch for comparison only and may
    return ``None`` when no shadow target is armed.  Exposes the
    ``WorkerView`` protocol for the scheduling policies.
    """

    def __init__(self, models: dict[str, Callable], *, max_batches: int = 4,
                 shadow_fn: Callable | None = None):
        if not models:
            raise ValueError("InferReplica: need at least one arm model")
        self.models = models
        self.shadow_fn = shadow_fn
        self.max_batches = max_batches
        self.queue: deque[_Batch] = deque()

    # -- constructors --------------------------------------------------------
    @staticmethod
    def from_forest(forest: Forest, attr_is_cont, *, impl: str = "vmap",
                    max_batches: int = 4) -> "InferReplica":
        return InferReplica(
            {"stable": _predict_fn(forest, attr_is_cont, impl=impl)},
            max_batches=max_batches)

    @staticmethod
    def from_handle(handle: ModelHandle, attr_is_cont, *,
                    impl: str = "vmap", max_batches: int = 4
                    ) -> "InferReplica":
        """Arm fns resolve through the handle at call time, so a
        ``refresh()`` / ``promote_canary()`` hot-swap reaches every replica
        without rebuilding the fleet."""
        cont = np.asarray(attr_is_cont, bool)

        def arm_fn(arm: str):
            def fn(x_rows: np.ndarray) -> np.ndarray:
                model = handle.stable if arm == "stable" else handle.canary
                if model is None:
                    raise RuntimeError(f"no {arm} model armed")
                return np.asarray(forest_predict(model, x_rows, cont,
                                                 impl=impl))
            return fn

        def shadow(x_rows: np.ndarray):
            model = handle.shadow_model()
            if model is None:
                return None
            return np.asarray(forest_predict(model, x_rows, cont, impl=impl))

        return InferReplica({"stable": arm_fn("stable"),
                             "canary": arm_fn("canary")},
                            max_batches=max_batches, shadow_fn=shadow)

    # -- WorkerView for the scheduling policies ------------------------------
    def queue_len(self) -> int:
        return len(self.queue)

    def queued_weight(self) -> float:
        return float(sum(len(b.requests) for b in self.queue))

    def capacity(self) -> int:
        return self.max_batches

    # -- admission / work ----------------------------------------------------
    def admit(self, batch: _Batch) -> None:
        if len(self.queue) >= self.max_batches:
            raise RuntimeError("replica queue full (scheduler race)")
        if batch.arm not in self.models:
            raise KeyError(f"replica has no {batch.arm!r} model")
        self.queue.append(batch)

    def drain(self) -> list[_Batch]:
        """Give back the queued batches (used on eviction)."""
        out = list(self.queue)
        self.queue.clear()
        return out

    def tick(self) -> tuple[list[PredictResult], dict | None]:
        """Serve one queued batch; returns (results, shadow_stats|None)."""
        if not self.queue:
            return [], None
        batch = self.queue.popleft()
        x = np.stack([r.x_row for r in batch.requests]).astype(np.int32)
        labels = np.asarray(self.models[batch.arm](x))
        shadow_stats = None
        if self.shadow_fn is not None:
            mirrored = self.shadow_fn(x)
            if mirrored is not None:
                shadow_stats = {
                    "rows": int(len(labels)),
                    "disagree": int((np.asarray(mirrored) != labels).sum()),
                }
        results = [
            PredictResult(uid=r.uid, label=int(labels[j]), replica=-1,
                          batch_size=len(batch.requests), arm=batch.arm)
            for j, r in enumerate(batch.requests)]
        return results, shadow_stats


class BatchPredictService:
    """Front door: microbatched admission over a fleet of infer replicas."""

    def __init__(self, replicas: list, *, handle: ModelHandle | None = None,
                 policy: str | Policy = "ws", speed_fn=None,
                 max_batch: int = 64, max_wait_ticks: int = 4,
                 max_requeues: int = 2,
                 tracer: obs_trace.Tracer | None = None,
                 metrics: obs_metrics.Registry | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.replicas = replicas
        self.handle = handle
        self.policy = policy if isinstance(policy, Policy) \
            else make_policy(policy, speed_fn=speed_fn)
        self.max_batch = max_batch
        self.max_wait_ticks = max_wait_ticks
        self.max_requeues = max_requeues
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        reg = metrics if metrics is not None else obs_metrics.REGISTRY
        self._m_submitted = reg.counter(
            "infer_requests_total", "predict requests submitted")
        self._m_results = reg.counter(
            "infer_results_total", "predict requests served, by arm")
        self._m_failed = reg.counter(
            "infer_failures_total", "terminal predict failures, by reason")
        self._m_evictions = reg.counter(
            "infer_evictions_total", "infer replicas evicted")
        self._m_requeues = reg.counter(
            "infer_requeues_total", "requests re-admitted after a fault")
        self._m_batches = reg.counter(
            "infer_replica_batches_total", "batches served, by replica")
        self._m_batch_rows = reg.histogram(
            "infer_batch_rows", "rows per dispatched batch",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096))
        self._m_queue_wait = reg.histogram(
            "infer_queue_wait_ticks", "ticks from submit to first dispatch")
        self._m_shadow = reg.counter(
            "infer_shadow_mirrored_total", "rows mirrored to the shadow arm")
        self._m_shadow_disagree = reg.counter(
            "infer_shadow_disagree_total",
            "mirrored rows whose shadow label differed")
        self.healthy = [True] * len(replicas)
        #: per-arm pending queues of (request, submit_tick)
        self.pending: dict[str, deque] = {}
        self.ready: deque[_Batch] = deque()
        self.results: list[PredictResult] = []
        self.failed: list[PredictFailure] = []
        self._requeues: dict[int, int] = {}
        self._submit_tick: dict[int, int] = {}
        self._dispatched: dict[int, bool] = {}
        self._inflight = 0
        self._tick = 0

    # ------------------------------------------------------------ admission
    def submit(self, req: PredictRequest) -> None:
        arm = self.handle.route(req.uid) if self.handle else "stable"
        self._submit_tick.setdefault(req.uid, self._tick)
        self._m_submitted.inc()
        self.tracer.begin("predict", id=req.uid, arm=arm)
        self.pending.setdefault(arm, deque()).append((req, self._tick))
        self._inflight += 1

    def _close_batches(self) -> None:
        """Move pending requests into ready batches: full batches always,
        partial ones when the oldest request aged past ``max_wait_ticks``."""
        for arm, q in self.pending.items():
            while q:
                aged = (self._tick - q[0][1]) >= self.max_wait_ticks
                if len(q) < self.max_batch and not aged:
                    break
                take = min(len(q), self.max_batch)
                reqs = [q.popleft()[0] for _ in range(take)]
                self.ready.append(_Batch(arm=arm, requests=reqs))

    # ------------------------------------------------------------- failures
    def _fail(self, uid: int, reason: str, detail: str = "") -> None:
        self.failed.append(PredictFailure(uid, reason, detail))
        self._m_failed.inc(reason=reason)
        self.tracer.end("predict", id=uid, outcome=reason)
        self._inflight -= 1

    def _requeue_requests(self, batch: _Batch, detail: str) -> None:
        """Return a failed batch's rows to their pending queue (front),
        charging each request's requeue budget."""
        q = self.pending.setdefault(batch.arm, deque())
        for req in reversed(batch.requests):
            n = self._requeues.get(req.uid, 0)
            if n >= self.max_requeues:
                self._fail(req.uid, "requeue_exhausted", detail)
                continue
            self._requeues[req.uid] = n + 1
            self._m_requeues.inc()
            q.appendleft((req, self._submit_tick[req.uid]))

    def _evict(self, i: int, detail: str) -> None:
        if not self.healthy[i]:
            return
        self.healthy[i] = False
        self._m_evictions.inc()
        self.tracer.instant("infer.replica.evict", replica=i, detail=detail)
        try:
            orphans = self.replicas[i].drain()
        except Exception:
            orphans = []
        for batch in orphans:
            self._requeue_requests(batch, f"replica {i} evicted: {detail}")

    # ------------------------------------------------------------- dispatch
    def _dispatch(self) -> None:
        # Same masking discipline as serve.engine: the policy always sees
        # the full replica list, with evicted replicas as zero-capacity
        # views, so stateful policies address physical indices forever.
        while self.ready:
            if not any(self.healthy):
                return
            views = []
            for i, rep in enumerate(self.replicas):
                if not self.healthy[i]:
                    views.append(QueueState(tasks=0, weight=0.0, cap=0))
                else:
                    views.append(QueueState(tasks=rep.queue_len(),
                                            weight=rep.queued_weight(),
                                            cap=rep.capacity()))
            batch = self.ready[0]
            i = self.policy.pick(batch.weight, views)
            if i is None:
                return                      # every healthy replica full
            self.ready.popleft()
            try:
                self.replicas[i].admit(batch)
            except RuntimeError as e:
                self.ready.appendleft(batch)        # scheduler race
                self.tracer.instant("infer.batch.race", detail=repr(e))
                return
            except Exception as e:
                self._evict(i, f"admit raised: {e!r}")
                self.ready.appendleft(batch)
                continue
            self._m_batch_rows.observe(len(batch.requests))
            for req in batch.requests:
                if not self._dispatched.get(req.uid):
                    self._dispatched[req.uid] = True
                    self._m_queue_wait.observe(
                        self._tick - self._submit_tick[req.uid])
            self.tracer.instant("infer.batch.dispatch", replica=i,
                                rows=len(batch.requests), arm=batch.arm)

    # ------------------------------------------------------------- main loop
    def step(self) -> None:
        """One engine tick: close, dispatch, serve."""
        self._tick += 1
        with self.tracer.span("infer.tick", tick=self._tick):
            self._close_batches()
            self._dispatch()
            for i, rep in enumerate(self.replicas):
                if not self.healthy[i]:
                    continue
                try:
                    with self.tracer.span(f"infer.replica{i}.tick"):
                        results, shadow = rep.tick()
                except Exception as e:
                    self._evict(i, f"tick raised: {e!r}")
                    continue
                if results:
                    self._m_batches.inc(replica=i)
                if shadow:
                    self._m_shadow.inc(shadow["rows"])
                    self._m_shadow_disagree.inc(shadow["disagree"])
                for r in results:
                    r.replica = i
                    self.results.append(r)
                    self._m_results.inc(arm=r.arm)
                    self.tracer.end("predict", id=r.uid, outcome="ok")
                    self._inflight -= 1

    def run_until_drained(self, *, max_ticks: int = 10_000
                          ) -> list[PredictResult]:
        """Tick until every submitted request has a terminal record.

        Mirrors ``serve.engine``: results in ``self.results``, explicit
        failure records in ``self.failed`` — nothing is dropped silently,
        including at ``max_ticks`` or after losing the last replica.
        """
        for _ in range(max_ticks):
            if self._inflight == 0:
                break
            # Partial batches never deadlock a drain: the tick counter keeps
            # advancing, so every pending row ages past max_wait_ticks and
            # closes (step() -> _close_batches).
            self.step()
            if not any(self.healthy) and self._inflight:
                self._fail_remaining("no_replicas", "all replicas evicted")
                break
        if self._inflight:
            self._fail_remaining("max_ticks",
                                 f"undrained after {max_ticks} ticks")
        return self.results

    def _fail_remaining(self, reason: str, detail: str) -> None:
        for q in self.pending.values():
            while q:
                req, _ = q.popleft()
                self._fail(req.uid, reason, detail)
        while self.ready:
            for req in self.ready.popleft().requests:
                self._fail(req.uid, reason, detail)
        for i, rep in enumerate(self.replicas):
            try:
                for batch in rep.drain():
                    for req in batch.requests:
                        self._fail(req.uid, reason, detail)
            except Exception:
                continue
        self._inflight = 0

    # ------------------------------------------------------------------ misc
    def stats(self) -> dict[str, Any]:
        reasons: dict[str, int] = {}
        for f in self.failed:
            reasons[f.reason] = reasons.get(f.reason, 0) + 1
        return dict(
            ticks=self._tick,
            results=len(self.results),
            failed=len(self.failed),
            failed_by_reason=reasons,
            requeues=sum(self._requeues.values()),
            evicted_replicas=[i for i, h in enumerate(self.healthy) if not h],
            healthy_replicas=sum(self.healthy),
        )
