"""A farm-with-feedback executor (FastFlow's D&C skeleton, paper Fig. 1/5).

Host-side, threaded implementation of the skeleton YaDT-FF is built on:

  * an *emitter* whose ``svc`` is called once with ``None`` at start-up and
    then once per task returned by a worker (the feedback channel);
  * ``n_workers`` *workers* whose ``svc`` processes one task and returns it;
  * per-worker bounded FIFO input queues + a MPSC feedback queue;
  * a pluggable scheduling policy (:mod:`repro.core.scheduler`).

The emitter signals completion by the farm observing zero in-flight tasks
with an idle emitter — the threaded analogue of the paper's
``noMoreTasks() && !nChilds`` test (§6.10).

On this container (1 CPU core) the farm cannot exhibit wall-clock speedup —
that is what :mod:`repro.core.simulate` measures — but the semantics are
real and the serving engine uses this class to dispatch requests across
model replicas with the paper's WS policy.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Sequence

from repro.core.scheduler import Policy, WS

GO_ON = object()   # FF_GO_ON: emitter consumed the feedback, keep running.


@dataclasses.dataclass
class Task:
    payload: Any
    weight: float = 1.0
    label: str = "BUILD_NODE"


class _Worker:
    def __init__(self, idx: int, capacity: int):
        self.idx = idx
        self.q: queue.Queue = queue.Queue(maxsize=capacity)
        self._weight = 0.0
        self._lock = threading.Lock()
        self.busy_time = 0.0
        self.n_tasks = 0

    # -- WorkerView protocol -------------------------------------------------
    def queue_len(self) -> int:
        return self.q.qsize()

    def queued_weight(self) -> float:
        with self._lock:
            return self._weight

    def capacity(self) -> int:
        return self.q.maxsize

    # -- weight accounting ---------------------------------------------------
    def add_weight(self, w: float) -> None:
        with self._lock:
            self._weight += w

    def done_weight(self, w: float) -> None:
        with self._lock:
            self._weight -= w


class Farm:
    """``ff_farm<ws_scheduler>`` (paper Fig. 5): emitter + workers + feedback."""

    def __init__(self, n_workers: int, *, policy: Policy | None = None,
                 queue_size: int = 4096):
        if n_workers < 1:
            raise ValueError("farm needs at least one worker")
        self.policy = policy or WS()
        cap = getattr(self.policy, "forced_capacity", queue_size)
        self.workers = [_Worker(i, cap) for i in range(n_workers)]
        self.feedback: queue.Queue = queue.Queue()
        self.emitter_busy = 0.0

    # ------------------------------------------------------------------ run
    def run(self,
            emitter_svc: Callable[[Any, Callable[[Any, float], None]], Any],
            worker_svc: Callable[[Any], Any]) -> dict[str, Any]:
        """Run to completion; returns execution-breakdown stats (cf. Fig 14)."""
        inflight = 0
        stop = object()

        def send_out(payload: Any, weight: float = 1.0) -> None:
            nonlocal inflight
            while True:
                i = self.policy.pick(weight, self.workers)
                if i is not None:
                    break
                time.sleep(0)          # all queues full: yield and retry
            wk = self.workers[i]
            wk.add_weight(weight)
            inflight += 1
            wk.q.put((payload, weight))

        def worker_loop(wk: _Worker) -> None:
            while True:
                item = wk.q.get()
                if item is stop:
                    return
                payload, weight = item
                t0 = time.perf_counter()
                result = worker_svc(payload)
                wk.busy_time += time.perf_counter() - t0
                wk.n_tasks += 1
                wk.done_weight(weight)
                self.feedback.put(result)

        threads = [threading.Thread(target=worker_loop, args=(w,), daemon=True)
                   for w in self.workers]
        for t in threads:
            t.start()

        t0 = time.perf_counter()
        emitter_svc(None, send_out)                 # start-up call (§6.2)
        self.emitter_busy += time.perf_counter() - t0
        while inflight > 0:
            result = self.feedback.get()
            inflight -= 1
            t0 = time.perf_counter()
            emitter_svc(result, send_out)           # feedback call
            self.emitter_busy += time.perf_counter() - t0

        for w in self.workers:
            w.q.put(stop)
        for t in threads:
            t.join()
        return dict(
            emitter_busy=self.emitter_busy,
            worker_busy=[w.busy_time for w in self.workers],
            worker_tasks=[w.n_tasks for w in self.workers],
        )
