"""A supervised farm-with-feedback executor (FastFlow's D&C skeleton, Fig. 1/5).

Host-side, threaded implementation of the skeleton YaDT-FF is built on:

  * an *emitter* whose ``svc`` is called once with ``None`` at start-up and
    then once per task returned by a worker (the feedback channel);
  * ``n_workers`` *workers* whose ``svc`` processes one task and returns it;
  * per-worker bounded FIFO input queues + a MPSC feedback queue;
  * a pluggable scheduling policy (:mod:`repro.core.scheduler`).

The emitter signals completion by the farm observing zero in-flight tasks
with an idle emitter — the threaded analogue of the paper's
``noMoreTasks() && !nChilds`` test (§6.10).

Unlike the paper's farm (which assumes workers never fail), this one is
**supervised**.  The run loop doubles as a supervisor that keeps the farm's
invariant — every dispatched task produces exactly one feedback event —
under worker crashes, hangs and deaths:

  * a ``worker_svc`` exception is captured and converted into an internal
    failure event; the task is retried on a surviving worker with bounded
    exponential backoff + jitter, and quarantined (surfaced to the emitter
    as a :class:`TaskFailure`) once it exhausts :class:`FaultPolicy` budget;
  * a per-attempt deadline (``FaultPolicy.task_deadline``) declares a hung
    worker dead and re-dispatches both its running task and its queued
    backlog to survivors; late results from a hung worker are dropped by
    attempt-tag matching;
  * a :class:`WorkerCrashed` exception kills the worker *thread* (the
    threaded analogue of a core going away); the farm degrades to fewer
    workers and fails the run — :class:`AllWorkersDead` — only when zero
    workers remain;
  * :meth:`Farm.run` returns the Fig-14 execution breakdown plus a failure
    breakdown (retries, requeues, quarantined tasks, timeouts, dead
    workers).

Deterministic failure modes for all of the above are injected by
:mod:`repro.core.faults`.  On this container (1 CPU core) the farm cannot
exhibit wall-clock speedup — that is what :mod:`repro.core.simulate`
measures — but the semantics are real: the serving engine dispatches
requests across model replicas with the paper's WS policy, and
:mod:`repro.core.farm_build` grows oracle-equal C4.5 trees through it.
"""

from __future__ import annotations

import dataclasses
import heapq
import queue
import random
import threading
import time
from typing import Any, Callable

from repro.core.scheduler import Policy, WS
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

GO_ON = object()   # FF_GO_ON: emitter consumed the feedback, keep running.

#: Thread-local set by the farm for the duration of each ``worker_svc`` call;
#: ``WORKER_CTX.idx`` is the worker index.  Used by :mod:`repro.core.faults`
#: to target specific workers without changing the ``worker_svc`` signature.
WORKER_CTX = threading.local()


class WorkerCrashed(Exception):
    """Raising this from ``worker_svc`` kills the *worker*, not the task.

    The threaded analogue of a worker process/core dying: the thread exits
    its loop, the supervisor re-dispatches the worker's queued tasks to
    survivors, and the farm degrades to fewer workers.
    """


class AllWorkersDead(RuntimeError):
    """The farm has work outstanding but zero live workers remain."""


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Knobs for the farm's supervision layer (see README "Fault model").

    ``max_retries``       re-dispatches granted per task after its first
                          failed attempt; attempt ``max_retries + 1`` failing
                          quarantines the task.
    ``quarantine_after``  override: total failed attempts before quarantine
                          (defaults to ``max_retries + 1``).
    ``backoff_*``         exponential backoff between retry dispatches:
                          ``base * factor**(failures-1)`` capped at ``max``.
    ``jitter``            the delay is scaled by U[1-jitter, 1+jitter]
                          (seeded; decorrelates retry storms).
    ``task_deadline``     per-attempt wall-clock budget in seconds.  A worker
                          over deadline is declared hung-dead and its work
                          re-dispatched.  ``None`` disables timeouts.
    """

    max_retries: int = 3
    quarantine_after: int | None = None
    backoff_base: float = 0.005
    backoff_factor: float = 2.0
    backoff_max: float = 0.25
    jitter: float = 0.5
    task_deadline: float | None = None
    seed: int = 0

    def attempts_allowed(self) -> int:
        if self.quarantine_after is not None:
            return max(1, self.quarantine_after)
        return self.max_retries + 1

    def backoff(self, failures: int, rng: random.Random) -> float:
        """Delay before re-dispatch after the ``failures``-th failure."""
        if self.backoff_base <= 0:
            return 0.0
        raw = self.backoff_base * self.backoff_factor ** max(failures - 1, 0)
        raw = min(raw, self.backoff_max)
        lo, hi = max(0.0, 1.0 - self.jitter), 1.0 + self.jitter
        return raw * rng.uniform(lo, hi)


@dataclasses.dataclass
class TaskFailure:
    """Feedback record for a task that exhausted its retry budget.

    Delivered to the emitter in place of a worker result; the emitter may
    re-emit it, substitute a fallback, or ignore it (the farm also appends
    it to ``Farm.quarantined`` either way).
    """

    payload: Any
    weight: float
    failures: int
    error: str


@dataclasses.dataclass
class Task:
    payload: Any
    weight: float = 1.0
    label: str = "BUILD_NODE"


@dataclasses.dataclass
class _Pending:
    """Supervisor-side record of one in-flight (or backoff-waiting) task."""

    payload: Any
    weight: float
    attempt: int = 0          # tag of the attempt currently in flight
    failures: int = 0
    waiting_retry: bool = False


class _Worker:
    def __init__(self, idx: int, capacity: int):
        self.idx = idx
        self.q: queue.Queue = queue.Queue()   # bound enforced via _occupancy
        self._cap = capacity
        self._weight = 0.0
        self._occupancy = 0       # queued + running attempts (supervisor view)
        self._lock = threading.Lock()
        self.busy_time = 0.0
        self.n_tasks = 0
        self.alive = True
        # (task_id, attempt, started_at) of the attempt being executed now.
        self.current: tuple[int, int, float] | None = None

    # -- WorkerView protocol -------------------------------------------------
    def queue_len(self) -> int:
        with self._lock:
            return self._occupancy

    def queued_weight(self) -> float:
        with self._lock:
            return self._weight

    def capacity(self) -> int:
        return self._cap if self.alive else 0

    # -- accounting (supervisor + worker thread) -----------------------------
    # ``_occupancy`` counts *queued* attempts (capacity semantics, as the
    # original qsize-based farm); ``_weight`` counts queued + running work
    # (the WS policy's view).  ``begin`` moves an attempt queued -> running.
    def add_load(self, w: float) -> None:
        with self._lock:
            self._weight += w
            self._occupancy += 1

    def begin(self) -> None:
        with self._lock:
            self._occupancy -= 1

    def done_weight(self, w: float) -> None:
        with self._lock:
            self._weight -= w

    def drop_queued(self, w: float) -> None:
        with self._lock:
            self._weight -= w
            self._occupancy -= 1


class Farm:
    """``ff_farm<ws_scheduler>`` (paper Fig. 5) with a supervision layer."""

    def __init__(self, n_workers: int, *, policy: Policy | None = None,
                 queue_size: int = 4096, fault: FaultPolicy | None = None,
                 health: Any | None = None,
                 tracer: obs_trace.Tracer | None = None,
                 metrics: obs_metrics.Registry | None = None):
        if n_workers < 1:
            raise ValueError("farm needs at least one worker")
        self.health = health
        if policy is None and health is not None:
            policy = health.policy()
        self.policy = policy or WS()
        cap = getattr(self.policy, "forced_capacity", queue_size)
        self.workers = [_Worker(i, cap) for i in range(n_workers)]
        self.feedback: queue.Queue = queue.Queue()
        self.emitter_busy = 0.0
        self.fault = fault or FaultPolicy()
        self.quarantined: list[TaskFailure] = []
        self._rng = random.Random(self.fault.seed)
        self._stats = dict(failures=0, retries=0, requeues=0, timeouts=0,
                           quarantined=0, dropped_late=0)
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        reg = metrics if metrics is not None else obs_metrics.REGISTRY
        self._m_dispatch = reg.counter(
            "farm_dispatch_total", "task attempts placed on worker queues")
        self._m_done = reg.counter(
            "farm_tasks_done_total", "task attempts completed ok")
        self._m_events = reg.counter(
            "farm_events_total", "supervision events, by event= label")
        self._m_task_s = reg.histogram(
            "farm_task_seconds", "worker_svc wall time per attempt")
        self._m_qweight = reg.gauge(
            "farm_queued_weight", "per-worker queued+running WS weight")

    def _bump(self, key: str) -> None:
        """One supervision event: mirror ``_stats`` into the metrics."""
        self._stats[key] += 1
        self._m_events.inc(event=key)

    # ------------------------------------------------------------------ run
    def run(self,
            emitter_svc: Callable[[Any, Callable[[Any, float], None]], Any],
            worker_svc: Callable[[Any], Any]) -> dict[str, Any]:
        """Run to completion; returns execution + failure breakdown stats."""
        stop = object()
        pending: dict[int, _Pending] = {}
        retry_heap: list[tuple[float, int]] = []   # (due_time, task_id)
        deferred: list = []          # non-death feedback taken while spinning
        notify: list[TaskFailure] = []   # quarantines awaiting the emitter
        next_id = iter(range(1 << 62)).__next__

        # ---------------- dispatch path ------------------------------------
        def alive(self=self) -> list[_Worker]:
            return [w for w in self.workers if w.alive]

        def poll_deaths() -> None:
            """Absorb worker-death events while the dispatch path is blocked.

            ``send_out`` may spin on full queues *inside* the emitter, before
            the main loop can read feedback; a worker dying then must still
            be noticed or the spin never ends.  Other feedback is deferred
            to the main loop untouched.
            """
            while True:
                try:
                    m = self.feedback.get_nowait()
                except queue.Empty:
                    return
                if m[0] == "died":
                    handle_died(m)
                else:
                    deferred.append(m)

        def dispatch(task_id: int) -> None:
            """Place the pending attempt on a live worker's queue."""
            rec = pending[task_id]
            rec.waiting_retry = False
            while True:
                i = self.policy.pick(rec.weight, self.workers)
                if i is not None and self.workers[i].alive:
                    break
                poll_deaths()
                if not alive():
                    raise AllWorkersDead(
                        f"{len(pending)} task(s) outstanding, 0 live workers")
                # all live queues full: let deadlines fire, yield and retry
                self._check_deadlines(on_worker_death)
                time.sleep(1e-4)
            wk = self.workers[i]
            wk.add_load(rec.weight)
            wk.q.put((task_id, rec.attempt, rec.payload, rec.weight))
            self._m_dispatch.inc()
            qw = wk.queued_weight()
            self._m_qweight.set(qw, worker=i)
            self.tracer.instant("task.dispatch", task=task_id,
                                attempt=rec.attempt, worker=i,
                                weight=rec.weight)
            self.tracer.counter(f"w{i}.queued_weight", weight=qw)

        def send_out(payload: Any, weight: float = 1.0) -> None:
            task_id = next_id()
            pending[task_id] = _Pending(payload=payload, weight=weight)
            dispatch(task_id)

        # ---------------- failure path -------------------------------------
        def on_failure(task_id: int, err: str) -> None:
            rec = pending[task_id]
            rec.failures += 1
            self._bump("failures")
            if rec.failures >= self.fault.attempts_allowed():
                del pending[task_id]
                fail = TaskFailure(payload=rec.payload, weight=rec.weight,
                                   failures=rec.failures, error=err)
                self.quarantined.append(fail)
                self._bump("quarantined")
                self.tracer.instant("task.quarantine", task=task_id,
                                    failures=rec.failures, error=err)
                notify.append(fail)      # delivered outside the dispatch path
                return
            self._bump("retries")
            rec.attempt += 1
            rec.waiting_retry = True
            delay = self.fault.backoff(rec.failures, self._rng)
            self.tracer.instant("task.retry", task=task_id,
                                failures=rec.failures, backoff_s=delay)
            heapq.heappush(retry_heap, (time.monotonic() + delay, task_id))

        def handle_died(msg) -> None:
            _, task_id, attempt, widx, err = msg
            on_worker_death(self.workers[widx], err)
            rec = pending.get(task_id)
            if rec is not None and rec.attempt == attempt \
                    and not rec.waiting_retry:
                on_failure(task_id, err)

        def on_worker_death(wk: _Worker, why: str) -> None:
            """Drain a dead worker: requeue its backlog, fail its current."""
            if not wk.alive:
                return
            wk.alive = False
            self._m_events.inc(event="worker_death")
            self.tracer.instant("worker.death", worker=wk.idx, why=why)
            if self.health is not None:
                self.health.on_worker_dead(wk.idx)
            cur = wk.current
            wk.current = None
            # Re-dispatch queued (never-started) attempts: not the task's
            # fault, so requeue without consuming retry budget.
            while True:
                try:
                    item = wk.q.get_nowait()
                except queue.Empty:
                    break
                if item is stop:
                    continue
                task_id, attempt, _, weight = item
                wk.drop_queued(weight)
                rec = pending.get(task_id)
                if rec is None or rec.attempt != attempt:
                    continue
                self._bump("requeues")
                self.tracer.instant("task.requeue", task=task_id,
                                    worker=wk.idx)
                dispatch(task_id)
            if cur is not None:
                task_id, attempt, _ = cur
                rec = pending.get(task_id)
                if rec is not None and rec.attempt == attempt \
                        and not rec.waiting_retry:
                    wk.done_weight(rec.weight)
                    on_failure(task_id, why)

        # ---------------- worker threads ------------------------------------
        def worker_loop(wk: _Worker) -> None:
            WORKER_CTX.idx = wk.idx
            while True:
                item = wk.q.get()
                if item is stop:
                    return
                task_id, attempt, payload, weight = item
                wk.begin()
                wk.current = (task_id, attempt, time.perf_counter())
                t0 = time.perf_counter()
                try:
                    with self.tracer.span("task", task=task_id,
                                          attempt=attempt, worker=wk.idx):
                        result = worker_svc(payload)
                except WorkerCrashed as e:
                    wk.current = None
                    wk.done_weight(weight)
                    self.feedback.put(
                        ("died", task_id, attempt, wk.idx, repr(e)))
                    return                      # thread exits: worker is gone
                except BaseException as e:      # crash -> failure feedback
                    wk.current = None
                    wk.done_weight(weight)
                    self.feedback.put(
                        ("fail", task_id, attempt, wk.idx, repr(e)))
                    continue
                dt = time.perf_counter() - t0
                wk.current = None
                wk.busy_time += dt
                wk.n_tasks += 1
                if wk.alive:      # hung-declared-dead: supervisor settled it
                    wk.done_weight(weight)
                self.feedback.put(("ok", task_id, attempt, wk.idx, result, dt))

        # ---------------- emitter ------------------------------------------
        def run_emitter(task: Any) -> None:
            t0 = time.perf_counter()
            with self.tracer.span("emitter"):
                emitter_svc(task, send_out)
            self.emitter_busy += time.perf_counter() - t0

        threads = [threading.Thread(target=worker_loop, args=(w,), daemon=True)
                   for w in self.workers]
        for t in threads:
            t.start()

        def flush_notify() -> None:
            while notify:
                run_emitter(notify.pop(0))

        try:
            run_emitter(None)                    # start-up call (§6.2)
            flush_notify()
            while pending:
                now = time.monotonic()
                while retry_heap and retry_heap[0][0] <= now:
                    _, task_id = heapq.heappop(retry_heap)
                    if task_id in pending and pending[task_id].waiting_retry:
                        dispatch(task_id)
                if deferred:
                    msg = deferred.pop(0)
                else:
                    timeout = self._poll_timeout(retry_heap, now)
                    try:
                        msg = self.feedback.get(timeout=timeout)
                    except queue.Empty:
                        self._check_deadlines(on_worker_death)
                        flush_notify()
                        continue
                kind, task_id, attempt, widx = msg[:4]
                if kind == "died":
                    # The thread is gone no matter how stale the attempt tag.
                    handle_died(msg)
                else:
                    rec = pending.get(task_id)
                    if rec is None or rec.attempt != attempt \
                            or rec.waiting_retry:
                        self._bump("dropped_late")        # superseded attempt
                    elif kind == "ok":
                        result, dt = msg[4], msg[5]
                        if self.health is not None:
                            self.health.on_task(widx, dt)
                        self._m_done.inc()
                        self._m_task_s.observe(dt)
                        qw = self.workers[widx].queued_weight()
                        self._m_qweight.set(qw, worker=widx)
                        self.tracer.counter(f"w{widx}.queued_weight",
                                            weight=qw)
                        del pending[task_id]
                        run_emitter(result)
                    else:                          # "fail"
                        on_failure(task_id, msg[4])
                flush_notify()
                if not alive() and pending:
                    raise AllWorkersDead(
                        f"{len(pending)} task(s) outstanding, 0 live workers")
        finally:
            for w in self.workers:
                if w.alive:
                    w.q.put(stop)
            for w, t in zip(self.workers, threads):
                t.join(timeout=None if w.alive else 0.1)
        return self.stats()

    # ---------------------------------------------------------------- utils
    def _poll_timeout(self, retry_heap, now: float) -> float | None:
        """Block on feedback only as long as no deadline/retry needs service."""
        candidates = []
        if retry_heap:
            candidates.append(max(0.0, retry_heap[0][0] - now))
        ddl = self.fault.task_deadline
        if ddl is not None:
            candidates.append(max(ddl / 4.0, 1e-3))
        return min(candidates) if candidates else None

    def _check_deadlines(self, on_worker_death) -> None:
        ddl = self.fault.task_deadline
        if ddl is None:
            return
        now = time.perf_counter()
        for wk in self.workers:
            cur = wk.current
            if wk.alive and cur is not None and now - cur[2] > ddl:
                self._bump("timeouts")
                self.tracer.instant("worker.timeout", worker=wk.idx)
                on_worker_death(
                    wk, f"deadline: worker {wk.idx} over {ddl:.3f}s budget")

    def stats(self) -> dict[str, Any]:
        """Fig-14 execution breakdown + supervision failure breakdown."""
        return dict(
            emitter_busy=self.emitter_busy,
            worker_busy=[w.busy_time for w in self.workers],
            worker_tasks=[w.n_tasks for w in self.workers],
            dead_workers=[w.idx for w in self.workers if not w.alive],
            n_live_workers=sum(w.alive for w in self.workers),
            **self._stats,
        )
