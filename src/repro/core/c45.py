"""Sequential YaDT oracle — the reference semantics for every other engine.

A direct transliteration of the paper's Fig. 2/3/4 pseudo-code:

  tree::build       -> :func:`build` (breadth-first frontier queue, Fig. 4)
  node::splitPre    -> class frequencies + stop tests
  node::splitAtt(i) -> per-attribute gain via the shared histogram scorer
  node::splitPost   -> argmax, threshold, child creation

It operates on the EC4.5 rank-space representation (:mod:`repro.core.binning`)
and calls the *same* jnp scoring functions as the SPMD engine
(:mod:`repro.core.entropy`) on identical ``(A, B, C)`` histogram tensors, so
split decisions are bitwise comparable.  Being the semantic reference it also
implements full C4.5 unknown handling (fractional weights to all children)
behind ``GrowConfig.unknown_fractional``.

This engine is intentionally plain numpy + per-node Python — it is the
measurement baseline ("Seq.Time" of paper Table 2) and the source of per-task
costs for the farm simulator.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import numpy as np

from repro.core import entropy
from repro.core.binning import BinnedDataset
from repro.core.config import GrowConfig
from repro.core.tree import Tree

EPS_W = 1e-7


@dataclasses.dataclass
class _Task:
    """A node task on the farm stream (paper's ff_task, weight = r cases)."""
    node_id: int
    idx: np.ndarray        # case indices at the node
    w: np.ndarray          # case weights (may be fractional: unknowns)
    active: np.ndarray     # bool (A,) attributes still active
    depth: int


@dataclasses.dataclass
class _Nodes:
    """Append-only builder for the Tree arrays (ids in BFS order)."""
    attr: list
    split_bin: list
    child0: list
    nchild: list
    cls: list
    freq: list
    depth: list

    @staticmethod
    def new() -> "_Nodes":
        return _Nodes([], [], [], [], [], [], [])

    def add(self, *, cls: int, freq: np.ndarray, depth: int) -> int:
        i = len(self.attr)
        self.attr.append(-1)
        self.split_bin.append(-1)
        self.child0.append(0)
        self.nchild.append(0)
        self.cls.append(cls)
        self.freq.append(freq)
        self.depth.append(depth)
        return i

    def finish(self, n_classes: int, capacity: int | None = None) -> Tree:
        import jax.numpy as jnp
        n = len(self.attr)
        cap = capacity or n
        t = Tree.empty(cap, n_classes)
        t.node_attr = t.node_attr.at[:n].set(np.asarray(self.attr, np.int32))
        t.node_split_bin = t.node_split_bin.at[:n].set(
            np.asarray(self.split_bin, np.int32))
        t.node_child0 = t.node_child0.at[:n].set(
            np.asarray(self.child0, np.int32))
        t.node_nchild = t.node_nchild.at[:n].set(
            np.asarray(self.nchild, np.int32))
        t.node_class = t.node_class.at[:n].set(np.asarray(self.cls, np.int32))
        t.node_freq = t.node_freq.at[:n].set(
            np.stack(self.freq).astype(np.float32))
        t.node_depth = t.node_depth.at[:n].set(
            np.asarray(self.depth, np.int32))
        t.n_nodes = jnp.int32(n)
        return t


def node_histogram(ds: BinnedDataset, idx: np.ndarray, w: np.ndarray,
                   b_max: int | None = None) -> np.ndarray:
    """(A, B, C) weighted counts of known-valued cases at a node."""
    a_dim = ds.n_attrs
    b_dim = b_max or ds.max_bins
    c_dim = ds.n_classes
    hist = np.zeros((a_dim, b_dim, c_dim), np.float32)
    xb = ds.x[idx]                       # (r, A)
    y = ds.y[idx]
    for a in range(a_dim):
        b = xb[:, a]
        known = b >= 0
        if not known.any():
            continue
        flat = b[known].astype(np.int64) * c_dim + y[known]
        hist[a] += np.bincount(flat, weights=w[known],
                               minlength=b_dim * c_dim
                               ).reshape(b_dim, c_dim).astype(np.float32)
    return hist


def class_frequencies(ds: BinnedDataset, idx: np.ndarray, w: np.ndarray
                      ) -> np.ndarray:
    """computeFrequencies (paper §2.2): weighted class counts at the node."""
    return np.bincount(ds.y[idx], weights=w, minlength=ds.n_classes
                       ).astype(np.float32)


def split_pre(freq: np.ndarray, depth: int, cfg: GrowConfig) -> bool:
    """onlyOneClass() || fewCases() (paper §2.3) — True = make a leaf."""
    total = float(freq.sum())
    pure = int((freq > EPS_W).sum()) <= 1
    return pure or total < 2 * cfg.min_objs or depth >= cfg.max_depth


def split_att(hist: np.ndarray, total_w: float, ds: BinnedDataset,
              cfg: GrowConfig):
    """gainCalculation for every attribute at once (paper §2.6-7, Fig. 3).

    Delegates to the shared jnp scorer so the oracle and the SPMD engine
    produce identical scores for identical histograms.
    """
    score, split_bin = entropy.gains_from_histogram(
        hist,
        total_w=np.float32(total_w),
        attr_is_cont=ds.attr_is_cont,
        n_bins=ds.n_bins,
        min_objs=cfg.min_objs,
        criterion=cfg.criterion,
    )
    return np.asarray(score), np.asarray(split_bin)


@dataclasses.dataclass
class SplitDecision:
    """Pure result of processing one node (splitPre+splitAtt+splitPost math).

    ``attr < 0`` means the node is a leaf.  Computing a decision mutates
    nothing — it is a function of (dataset, task) only — so the farm may
    retry it on any worker after a crash without corrupting the build
    (:mod:`repro.core.farm_build`).
    """

    attr: int = -1
    split_bin: int = -1                 # threshold bin (continuous), else -1
    n_children: int = 0
    child_active: np.ndarray | None = None
    child_idx: list = dataclasses.field(default_factory=list)
    child_w: list = dataclasses.field(default_factory=list)
    child_freq: list = dataclasses.field(default_factory=list)
    child_cls: list = dataclasses.field(default_factory=list)

    @property
    def is_leaf(self) -> bool:
        return self.attr < 0


def split_node(ds: BinnedDataset, cfg: GrowConfig, *, idx: np.ndarray,
               w: np.ndarray, active: np.ndarray, depth: int,
               freq: np.ndarray, cls: int) -> SplitDecision:
    """Process one node: the paper's splitPre/splitAtt/splitPost pipeline.

    Shared verbatim by the sequential oracle (:func:`build`) and the farm
    workers (:mod:`repro.core.farm_build`), so both engines make bitwise
    identical split decisions.
    """
    if split_pre(freq, depth, cfg):
        return SplitDecision()

    hist = node_histogram(ds, idx, w)
    total_w = float(w.sum())
    score, split_bin = split_att(hist, total_w, ds, cfg)
    best_attr, best_score, has_split = entropy.pick_best_attribute(
        np.asarray(score)[None, :], np.asarray(active)[None, :])
    best_attr = int(best_attr[0])
    if not bool(has_split[0]):
        return SplitDecision()

    a = best_attr
    is_cont = bool(ds.attr_is_cont[a])
    sb = int(split_bin[a])
    n_children = 2 if is_cont else int(ds.n_bins[a])

    # --- partition cases over the children (paper §2.12-14) ---------------
    b_col = ds.x[idx, a]
    known = b_col >= 0
    if is_cont:
        child_of = np.where(b_col <= sb, 0, 1)
    else:
        child_of = b_col.astype(np.int64)
    child_known_w = np.zeros(n_children, np.float64)
    np.add.at(child_known_w, child_of[known], w[known])
    w_known = float(child_known_w.sum())
    heaviest = int(np.argmax(child_known_w))

    child_idx: list[np.ndarray] = []
    child_w: list[np.ndarray] = []
    for j in range(n_children):
        sel = known & (child_of == j)
        ci, cw = idx[sel], w[sel]
        if (~known).any():
            if cfg.unknown_fractional:
                # Full C4.5: every child receives the unknown cases with
                # weight rescaled by its share of the known weight.
                share = child_known_w[j] / max(w_known, EPS_W)
                if share > 0:
                    ci = np.concatenate([ci, idx[~known]])
                    cw = np.concatenate(
                        [cw, (w[~known] * share).astype(np.float32)])
            elif j == heaviest:
                ci = np.concatenate([ci, idx[~known]])
                cw = np.concatenate([cw, w[~known]])
        child_idx.append(ci)
        child_w.append(cw.astype(np.float32))

    child_active = active.copy()
    if not is_cont:
        child_active[a] = False       # discrete attr consumed (paper §2.6)
    child_freq, child_cls = [], []
    for j in range(n_children):
        cfreq = class_frequencies(ds, child_idx[j], child_w[j]) \
            if len(child_idx[j]) else np.zeros(ds.n_classes, np.float32)
        ccls = int(np.argmax(cfreq)) if cfreq.sum() > EPS_W else int(cls)
        child_freq.append(cfreq)
        child_cls.append(ccls)
    return SplitDecision(attr=a, split_bin=sb if is_cont else -1,
                         n_children=n_children, child_active=child_active,
                         child_idx=child_idx, child_w=child_w,
                         child_freq=child_freq, child_cls=child_cls)


def build(ds: BinnedDataset, cfg: GrowConfig = GrowConfig(),
          *, task_trace: list | None = None,
          capacity: int | None = None,
          attr_mask: np.ndarray | None = None,
          case_w: np.ndarray | None = None) -> Tree:
    """Breadth-first C4.5 growth (paper Fig. 4, tree::build).

    ``task_trace``, when given, records one entry per processed node:
    ``(node_id, parent_id, r, c, n_children)`` — the exact task DAG the farm
    simulator replays (weights = r, as in the paper's WS policy).

    ``attr_mask`` (bool (A,)) restricts the split search to a subset of
    attributes and ``case_w`` (f32 (N,)) overrides the per-case weights —
    the ensemble trainer's per-tree feature-subset / bootstrap hooks
    (:mod:`repro.ensemble.sampling`).  Both default to the full dataset, so
    every engine keeps sharing one :class:`BinnedDataset` instead of
    materialising per-tree copies.
    """
    nodes = _Nodes.new()
    n = ds.n_cases
    root_idx = np.arange(n, dtype=np.int64)
    w_base = ds.w if case_w is None else np.asarray(case_w)
    root_w = w_base.astype(np.float32).copy()
    root_active = (np.ones(ds.n_attrs, dtype=bool) if attr_mask is None
                   else np.asarray(attr_mask, dtype=bool).copy())
    root_freq = class_frequencies(ds, root_idx, root_w)
    root = nodes.add(cls=int(np.argmax(root_freq)), freq=root_freq, depth=0)
    q: deque[_Task] = deque()
    q.append(_Task(root, root_idx, root_w, root_active, 0))
    parent_of = {root: -1}

    while q:
        t = q.popleft()
        dec = split_node(ds, cfg, idx=t.idx, w=t.w, active=t.active,
                         depth=t.depth, freq=nodes.freq[t.node_id],
                         cls=int(nodes.cls[t.node_id]))
        if dec.is_leaf:
            _trace(task_trace, t, parent_of, 0, ds)
            continue

        # --- emit children in sibling order (BFS ids, same as frontier) ---
        nodes.attr[t.node_id] = dec.attr
        nodes.split_bin[t.node_id] = dec.split_bin
        nodes.nchild[t.node_id] = dec.n_children
        first = None
        for j in range(dec.n_children):
            cid = nodes.add(cls=dec.child_cls[j], freq=dec.child_freq[j],
                            depth=t.depth + 1)
            parent_of[cid] = t.node_id
            if first is None:
                first = cid
            q.append(_Task(cid, dec.child_idx[j], dec.child_w[j],
                           dec.child_active, t.depth + 1))
        nodes.child0[t.node_id] = first
        _trace(task_trace, t, parent_of, dec.n_children, ds)

    return nodes.finish(ds.n_classes, capacity)


def _trace(trace: list | None, t: _Task, parent_of: dict, n_children: int,
           ds: BinnedDataset) -> None:
    if trace is not None:
        trace.append(dict(node_id=t.node_id, parent=parent_of[t.node_id],
                          r=len(t.idx), c=int(t.active.sum()),
                          n_children=n_children, depth=t.depth))
