"""Farm task-scheduling policies (paper Sect. 5, Fig. 13).

The emitter assigns each outgoing task to a worker queue according to one of:

  DRR — Dynamic Round-Robin: cycle through workers, skipping full queues
        (paper uses queue size 4096).
  OD  — On-Demand: DRR with queue size 1 (fully online).
  WS  — Weighted Scheduling: the paper's contribution — each task carries a
        weight (= r, the number of cases at the node) and goes to the worker
        with the lowest total queued+running weight.

Policies are pure-Python and deliberately tiny: they are shared by the real
threaded farm (:mod:`repro.core.farm`), the discrete-event simulator
(:mod:`repro.core.simulate`) and the serving engine's request dispatcher.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence


class WorkerView(Protocol):
    """What a policy may observe about a worker (FastFlow lock-free queues
    expose exactly queue occupancy; WS additionally tracks weights)."""

    def queue_len(self) -> int: ...
    def queued_weight(self) -> float: ...
    def capacity(self) -> int: ...


@dataclasses.dataclass
class QueueState:
    """Plain-data WorkerView used by the simulator and tests."""
    tasks: int = 0
    weight: float = 0.0
    cap: int = 4096

    def queue_len(self) -> int:
        return self.tasks

    def queued_weight(self) -> float:
        return self.weight

    def capacity(self) -> int:
        return self.cap


class Policy:
    name = "base"

    def pick(self, weight: float, workers: Sequence[WorkerView]) -> int | None:
        """Return the worker index, or None when every queue is full."""
        raise NotImplementedError


class DRR(Policy):
    """Dynamic Round-Robin, skipping workers with a full input queue."""

    name = "drr"

    def __init__(self) -> None:
        self._next = 0

    def pick(self, weight: float, workers: Sequence[WorkerView]) -> int | None:
        n = len(workers)
        for off in range(n):
            i = (self._next + off) % n
            if workers[i].queue_len() < workers[i].capacity():
                self._next = (i + 1) % n
                return i
        return None


class OD(DRR):
    """On-Demand: DRR over queues of capacity 1 (the farm enforces cap=1)."""

    name = "od"
    forced_capacity = 1


class WS(Policy):
    """Weighted Scheduling: least total queued weight wins (ties: lowest id).

    This is the policy the paper adds to FastFlow for YaDT-FF; with task
    weight = r it behaves like an efficient online scheduler (Fig. 13).
    """

    name = "ws"

    def pick(self, weight: float, workers: Sequence[WorkerView]) -> int | None:
        best, best_w = None, float("inf")
        for i, wk in enumerate(workers):
            if wk.queue_len() >= wk.capacity():
                continue
            qw = wk.queued_weight()
            if qw < best_w:
                best, best_w = i, qw
        return best


class HealthWS(WS):
    """WS scaled by per-worker health: projected-finish-time scheduling.

    ``speed_fn`` returns ``{worker_index: speed}`` — the relative throughput
    factors from :meth:`repro.train.elastic.StragglerMonitor.ws_weights`
    (fleet_median / worker_median; a straggler scores < 1).  A worker's
    effective load is ``(queued_weight + task_weight) / speed``, so slow
    hosts receive proportionally less work.  Speed 0 marks a worker
    unhealthy (heartbeat-failed): it is skipped entirely unless every
    healthy queue is full, in which case plain WS over whatever has
    capacity is the fallback (progress beats placement).
    """

    name = "health_ws"

    def __init__(self, speed_fn) -> None:
        self.speed_fn = speed_fn

    def pick(self, weight: float, workers: Sequence[WorkerView]) -> int | None:
        speeds = self.speed_fn() or {}
        best, best_w = None, float("inf")
        fallback, fallback_w = None, float("inf")
        for i, wk in enumerate(workers):
            if wk.queue_len() >= wk.capacity():
                continue
            qw = wk.queued_weight()
            if qw < fallback_w:
                fallback, fallback_w = i, qw
            speed = speeds.get(i, 1.0)
            if speed <= 0.0:
                continue
            eff = (qw + weight) / speed
            if eff < best_w:
                best, best_w = i, eff
        return best if best is not None else fallback


def make_policy(name: str, *, speed_fn=None) -> Policy:
    """Policy factory by name: ``drr | od | ws | health_ws``.

    ``speed_fn`` is the :class:`HealthWS` hook (``{worker_index: speed}``,
    e.g. :meth:`repro.train.elastic.FarmHealth.speeds`); with no hook every
    worker scores speed 1.0 and ``health_ws`` degenerates to plain WS.
    """
    name = name.lower()
    if name == "drr":
        return DRR()
    if name == "od":
        return OD()
    if name == "ws":
        return WS()
    if name == "health_ws":
        return HealthWS(speed_fn if speed_fn is not None else dict)
    raise ValueError(
        f"unknown scheduling policy {name!r} (drr|od|ws|health_ws)")
