"""Deterministic fault injection for the farm and serving stack.

Every failure mode the supervised farm (:mod:`repro.core.farm`) and the
serving engine (:mod:`repro.serve.engine`) must tolerate can be injected
here, *deterministically*: decisions are a pure hash of
``(seed, task key, call number)``, so the same seed produces the same fault
schedule regardless of thread interleaving — chaos tests are replayable.

Farm side — :class:`FaultInjector` wraps a ``worker_svc``:

  * ``crash_p``  — the task attempt raises :class:`InjectedCrash`
                   (worker survives; supervisor retries the task);
  * ``die_p``    — the *worker* raises :class:`~repro.core.farm.WorkerCrashed`
                   (thread death; farm degrades to fewer workers);
  * ``hang_p``   — the attempt sleeps ``hang_s`` seconds (a task deadline
                   should declare the worker hung-dead first);
  * ``slow_p``   — the attempt sleeps ``slow_s`` then completes normally
                   (straggler; exercises WS/health rebalancing);
  * ``dead_workers`` — these worker indices die on their first task
                   (a permanently lost core).

Serving side — :class:`ChaosReplica` proxies a ``serve.engine.Replica`` and
kills it (raises from ``tick``/``admit``) at a chosen tick, so replica
failover is unit-testable without real hardware faults.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Callable

from repro.core.farm import WORKER_CTX, WorkerCrashed


class InjectedCrash(RuntimeError):
    """A fault-injected task failure (the worker itself survives)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Probabilities (per task attempt) and magnitudes of injected faults.

    Probabilities are evaluated in order crash -> die -> hang -> slow on one
    uniform draw, so they must sum to <= 1.
    """

    crash_p: float = 0.0
    die_p: float = 0.0
    hang_p: float = 0.0
    slow_p: float = 0.0
    hang_s: float = 2.0
    slow_s: float = 0.02
    dead_workers: frozenset = frozenset()

    def __post_init__(self):
        if self.crash_p + self.die_p + self.hang_p + self.slow_p > 1.0:
            raise ValueError("fault probabilities must sum to <= 1")


class FaultInjector:
    """Seeded, schedule-deterministic fault wrapper for a ``worker_svc``.

    ``key_fn`` maps a task payload to a stable key (default ``repr``); the
    n-th call for a given key always draws the same fault decision for a
    given seed, independent of which worker runs it or when.
    """

    def __init__(self, seed: int = 0, spec: FaultSpec | None = None, *,
                 key_fn: Callable[[Any], Any] = repr):
        self.seed = seed
        self.spec = spec or FaultSpec()
        self.key_fn = key_fn
        self._calls: dict[Any, int] = {}
        self._lock = threading.Lock()
        self.log: list[tuple[Any, int, str]] = []   # (key, call#, action)

    def _draw(self, key: Any, call: int) -> float:
        digest = hashlib.sha256(
            f"{self.seed}|{key}|{call}".encode()).digest()
        return int.from_bytes(digest[:8], "big") / float(1 << 64)

    def decide(self, key: Any, call: int) -> str:
        u = self._draw(key, call)
        s = self.spec
        for p, action in ((s.crash_p, "crash"), (s.die_p, "die"),
                          (s.hang_p, "hang"), (s.slow_p, "slow")):
            if u < p:
                return action
            u -= p
        return "ok"

    def wrap_worker(self, svc: Callable[[Any], Any]) -> Callable[[Any], Any]:
        def wrapped(payload: Any) -> Any:
            widx = getattr(WORKER_CTX, "idx", None)
            if widx is not None and widx in self.spec.dead_workers:
                raise WorkerCrashed(f"injected: worker {widx} is dead")
            key = self.key_fn(payload)
            with self._lock:
                call = self._calls.get(key, 0)
                self._calls[key] = call + 1
            action = self.decide(key, call)
            with self._lock:
                self.log.append((key, call, action))
            if action == "crash":
                raise InjectedCrash(f"injected crash: task {key} try {call}")
            if action == "die":
                raise WorkerCrashed(f"injected death: worker {widx}")
            if action == "hang":
                time.sleep(self.spec.hang_s)
            elif action == "slow":
                time.sleep(self.spec.slow_s)
            return svc(payload)
        return wrapped


class ChaosReplica:
    """Proxy a serving ``Replica``; kill it at a chosen engine tick.

    ``fail_at_tick``  — ``tick()`` raises :class:`InjectedCrash` on the n-th
                        call (1-based) and every call after it.
    ``admit_failures``— the first n ``admit()`` calls raise the scheduler-race
                        ``RuntimeError`` the engine must absorb by requeueing.
    """

    def __init__(self, replica: Any, *, fail_at_tick: int | None = None,
                 admit_failures: int = 0):
        self._inner = replica
        self.fail_at_tick = fail_at_tick
        self.admit_failures = admit_failures
        self.ticks = 0

    def tick(self):
        self.ticks += 1
        if self.fail_at_tick is not None and self.ticks >= self.fail_at_tick:
            raise InjectedCrash(f"injected replica death at tick {self.ticks}")
        return self._inner.tick()

    def admit(self, req):
        if self.admit_failures > 0:
            self.admit_failures -= 1
            raise RuntimeError("no free slot (injected scheduler race)")
        return self._inner.admit(req)

    def __getattr__(self, name):
        return getattr(self._inner, name)
