"""Fixed-capacity array decision tree.

Both engines (the sequential YaDT oracle and the SPMD frontier builder) emit
this structure, so trees are directly comparable and prediction is one shared
vectorized routine.

Layout (capacity M, C classes):

  node_attr[i]      int32  attribute tested at node i, -1 for a leaf
  node_split_bin[i] int32  continuous: threshold bin (test: x <= bin);
                           discrete: -1 (child index == the value's bin)
  node_child0[i]    int32  id of the first child (children are contiguous)
  node_nchild[i]    int32  number of children (0 for leaves)
  node_class[i]     int32  majority class (prediction fallback at every node)
  node_freq[i, c]   f32    weighted class frequencies seen at the node
  node_depth[i]     int32  root = 0
  n_nodes           int    live prefix of the arrays
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Tree:
    node_attr: jnp.ndarray
    node_split_bin: jnp.ndarray
    node_child0: jnp.ndarray
    node_nchild: jnp.ndarray
    node_class: jnp.ndarray
    node_freq: jnp.ndarray
    node_depth: jnp.ndarray
    n_nodes: jnp.ndarray  # int32 scalar

    @staticmethod
    def empty(capacity: int, n_classes: int) -> "Tree":
        return Tree(
            node_attr=jnp.full((capacity,), -1, jnp.int32),
            node_split_bin=jnp.full((capacity,), -1, jnp.int32),
            node_child0=jnp.zeros((capacity,), jnp.int32),
            node_nchild=jnp.zeros((capacity,), jnp.int32),
            node_class=jnp.zeros((capacity,), jnp.int32),
            node_freq=jnp.zeros((capacity, n_classes), jnp.float32),
            node_depth=jnp.zeros((capacity,), jnp.int32),
            n_nodes=jnp.int32(0),
        )

    # ---- host-side conveniences (numpy views, cut to the live prefix) ----

    def to_numpy(self) -> "Tree":
        return jax.tree.map(np.asarray, self)

    @property
    def size(self) -> int:
        return int(self.n_nodes)

    @property
    def depth(self) -> int:
        n = self.size
        return int(np.max(np.asarray(self.node_depth)[:n])) if n else 0

    @property
    def n_leaves(self) -> int:
        n = self.size
        return int(np.sum(np.asarray(self.node_nchild)[:n] == 0))

    def pretty(self, max_nodes: int = 40) -> str:
        t = self.to_numpy()
        lines = []
        for i in range(min(self.size, max_nodes)):
            pad = "  " * int(t.node_depth[i])
            if t.node_nchild[i] == 0:
                lines.append(f"{pad}#{i} leaf -> class {int(t.node_class[i])}")
            else:
                lines.append(
                    f"{pad}#{i} attr {int(t.node_attr[i])}"
                    f" bin<={int(t.node_split_bin[i])}"
                    f" children [{int(t.node_child0[i])}.."
                    f"{int(t.node_child0[i]) + int(t.node_nchild[i]) - 1}]")
        if self.size > max_nodes:
            lines.append(f"... ({self.size - max_nodes} more)")
        return "\n".join(lines)


def _descend_once(tree: Tree, attr_is_cont: jnp.ndarray, node: jnp.ndarray,
                  x_row_bins: jnp.ndarray) -> jnp.ndarray:
    """One routing step for a batch of cases sitting at ``node``."""
    attr = tree.node_attr[node]
    nchild = tree.node_nchild[node]
    is_leaf = nchild == 0
    b = jnp.take_along_axis(x_row_bins, jnp.maximum(attr, 0)[:, None],
                            axis=1)[:, 0]
    cont = attr_is_cont[jnp.maximum(attr, 0)]
    child_cont = jnp.where(b <= tree.node_split_bin[node], 0, 1)
    child = jnp.where(cont, child_cont, b).astype(jnp.int32)
    # Unknown value: C4.5 prediction follows the heaviest child; we route to
    # the child holding the largest weight — precomputed as node_class-side
    # fallback: follow child 0..nchild-1 with max freq.  We approximate with
    # the majority-weight child recorded during growth via node_class of the
    # children; for simplicity route unknowns to the heaviest child by weight.
    heaviest = _heaviest_child(tree, node, nchild)
    child = jnp.where(b < 0, heaviest, child)
    child = jnp.clip(child, 0, jnp.maximum(nchild - 1, 0))
    nxt = tree.node_child0[node] + child
    return jnp.where(is_leaf, node, nxt)


def _heaviest_child(tree: Tree, node: jnp.ndarray, nchild: jnp.ndarray
                    ) -> jnp.ndarray:
    """Index (0-based among siblings) of the child with the largest weight."""
    c0 = tree.node_child0[node]
    max_h = 8  # scan a bounded window; trees with wider splits fall back to 0
    ws = []
    for j in range(max_h):
        cid = c0 + j
        valid = j < nchild
        ws.append(jnp.where(valid, jnp.sum(tree.node_freq[cid], axis=-1),
                            -jnp.inf))
    return jnp.argmax(jnp.stack(ws, axis=-1), axis=-1).astype(jnp.int32)


def predict(tree: Tree, x_bins: jnp.ndarray, attr_is_cont: jnp.ndarray,
            max_depth: int = 64) -> jnp.ndarray:
    """Vectorized class prediction for binned cases ``x_bins (N, A)``."""
    x_bins = jnp.asarray(x_bins, jnp.int32)
    attr_is_cont = jnp.asarray(attr_is_cont, bool)
    node = jnp.zeros((x_bins.shape[0],), jnp.int32)

    def body(_, node):
        return _descend_once(tree, attr_is_cont, node, x_bins)

    node = jax.lax.fori_loop(0, max_depth, body, node)
    return tree.node_class[node]


def trees_equal(a: Tree, b: Tree, *, freq_tol: float = 1e-3) -> bool:
    """Structural equality of the live prefixes (host-side, for tests)."""
    a, b = a.to_numpy(), b.to_numpy()
    na, nb = int(a.n_nodes), int(b.n_nodes)
    if na != nb:
        return False
    for f in ("node_attr", "node_split_bin", "node_child0", "node_nchild",
              "node_class", "node_depth"):
        if not np.array_equal(getattr(a, f)[:na], getattr(b, f)[:na]):
            return False
    return bool(np.allclose(a.node_freq[:na], b.node_freq[:na],
                            atol=freq_tol, rtol=1e-4))
