"""Fixed-capacity array decision tree.

Both engines (the sequential YaDT oracle and the SPMD frontier builder) emit
this structure, so trees are directly comparable and prediction is one shared
vectorized routine.

Layout (capacity M, C classes):

  node_attr[i]      int32  attribute tested at node i, -1 for a leaf
  node_split_bin[i] int32  continuous: threshold bin (test: x <= bin);
                           discrete: -1 (child index == the value's bin)
  node_child0[i]    int32  id of the first child (children are contiguous)
  node_nchild[i]    int32  number of children (0 for leaves)
  node_class[i]     int32  majority class (prediction fallback at every node)
  node_freq[i, c]   f32    weighted class frequencies seen at the node
  node_depth[i]     int32  root = 0
  n_nodes           int    live prefix of the arrays
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Tree:
    node_attr: jnp.ndarray
    node_split_bin: jnp.ndarray
    node_child0: jnp.ndarray
    node_nchild: jnp.ndarray
    node_class: jnp.ndarray
    node_freq: jnp.ndarray
    node_depth: jnp.ndarray
    n_nodes: jnp.ndarray  # int32 scalar

    @staticmethod
    def empty(capacity: int, n_classes: int) -> "Tree":
        return Tree(
            node_attr=jnp.full((capacity,), -1, jnp.int32),
            node_split_bin=jnp.full((capacity,), -1, jnp.int32),
            node_child0=jnp.zeros((capacity,), jnp.int32),
            node_nchild=jnp.zeros((capacity,), jnp.int32),
            node_class=jnp.zeros((capacity,), jnp.int32),
            node_freq=jnp.zeros((capacity, n_classes), jnp.float32),
            node_depth=jnp.zeros((capacity,), jnp.int32),
            n_nodes=jnp.int32(0),
        )

    # ---- host-side conveniences (numpy views, cut to the live prefix) ----

    def to_numpy(self) -> "Tree":
        return jax.tree.map(np.asarray, self)

    @property
    def size(self) -> int:
        return int(self.n_nodes)

    @property
    def depth(self) -> int:
        n = self.size
        return int(np.max(np.asarray(self.node_depth)[:n])) if n else 0

    @property
    def n_leaves(self) -> int:
        n = self.size
        return int(np.sum(np.asarray(self.node_nchild)[:n] == 0))

    def pretty(self, max_nodes: int = 40) -> str:
        t = self.to_numpy()
        lines = []
        for i in range(min(self.size, max_nodes)):
            pad = "  " * int(t.node_depth[i])
            if t.node_nchild[i] == 0:
                lines.append(f"{pad}#{i} leaf -> class {int(t.node_class[i])}")
            else:
                lines.append(
                    f"{pad}#{i} attr {int(t.node_attr[i])}"
                    f" bin<={int(t.node_split_bin[i])}"
                    f" children [{int(t.node_child0[i])}.."
                    f"{int(t.node_child0[i]) + int(t.node_nchild[i]) - 1}]")
        if self.size > max_nodes:
            lines.append(f"... ({self.size - max_nodes} more)")
        return "\n".join(lines)


def descend_once(attr_is_cont: jnp.ndarray, node: jnp.ndarray,
                 x_row_bins: jnp.ndarray, *, node_attr: jnp.ndarray,
                 node_split_bin: jnp.ndarray, node_child0: jnp.ndarray,
                 node_nchild: jnp.ndarray, heavy: jnp.ndarray) -> jnp.ndarray:
    """One routing step for a batch of cases sitting at ``node``.

    Shared by :func:`predict` and the packed-forest batched predictor
    (:mod:`repro.infer.forest`), which vmaps it over stacked node arrays —
    hence the keyword array arguments instead of a :class:`Tree`.
    ``heavy`` is the precomputed :func:`heavy_child_table`.
    """
    attr = node_attr[node]
    nchild = node_nchild[node]
    is_leaf = nchild == 0
    b = jnp.take_along_axis(x_row_bins, jnp.maximum(attr, 0)[:, None],
                            axis=1)[:, 0]
    cont = attr_is_cont[jnp.maximum(attr, 0)]
    child_cont = jnp.where(b <= node_split_bin[node], 0, 1)
    child = jnp.where(cont, child_cont, b).astype(jnp.int32)
    # Unknown value: C4.5 prediction follows the heaviest child (the child
    # holding the largest total case weight), matching splitPost routing.
    child = jnp.where(b < 0, heavy[node], child)
    child = jnp.clip(child, 0, jnp.maximum(nchild - 1, 0))
    nxt = node_child0[node] + child
    return jnp.where(is_leaf, node, nxt)


def heavy_child_table(node_child0: jnp.ndarray, node_nchild: jnp.ndarray,
                      node_freq: jnp.ndarray) -> jnp.ndarray:
    """Per-node sibling rank of the heaviest child, exact for any arity.

    Returns ``heavy (M,) int32`` with ``heavy[i]`` = 0-based index among
    node i's children of the child with the largest total weight (first one
    on ties, matching ``np.argmax``); 0 for leaves.  All static-shape
    vectorized ops, so it is jit-safe and replaces the old bounded
    ``max_h = 8`` window that silently mis-routed unknown values on nodes
    with more than 8 children.

    Relies on the BFS layout shared by every engine: children are contiguous
    and ``node_child0`` is non-decreasing over emitting nodes, so sibling
    blocks tile the id space and a cumulative max over block-start marks
    recovers each node's parent.
    """
    m = node_child0.shape[0]
    ids = jnp.arange(m, dtype=jnp.int32)
    internal = node_nchild > 0
    # parent[j] for every non-root node j (roots/padding resolve to -1)
    marks = jnp.full((m,), -1, jnp.int32).at[
        jnp.where(internal, node_child0, 0)].max(
        jnp.where(internal, ids, -1))
    parent = jax.lax.cummax(marks)
    p_idx = jnp.where(parent >= 0, parent, 0)
    rank = ids - node_child0[p_idx]
    # Padding past the live prefix inherits the last block's parent from the
    # cummax: the rank-range check rules those positions out.
    is_child = (parent >= 0) & (rank >= 0) & (rank < node_nchild[p_idx])
    w = jnp.sum(node_freq, axis=-1)
    # heaviest weight among each parent's children, scattered back per child
    max_w = jnp.full((m,), -jnp.inf, node_freq.dtype).at[p_idx].max(
        jnp.where(is_child, w, -jnp.inf))
    is_best = is_child & (w >= max_w[p_idx])
    big = jnp.int32(1 << 30)
    heavy = jnp.full((m,), big, jnp.int32).at[p_idx].min(
        jnp.where(is_best, rank, big))
    return jnp.where(internal & (heavy < big), heavy, 0).astype(jnp.int32)


def predict(tree: Tree, x_bins: jnp.ndarray, attr_is_cont: jnp.ndarray,
            max_depth: int | None = None) -> jnp.ndarray:
    """Vectorized class prediction for binned cases ``x_bins (N, A)``.

    ``max_depth`` (the descent's trip count) defaults to
    ``node_depth.max() + 1`` over the live prefix, so deep trees classify at
    their true leaves instead of silently truncating at a fixed budget.
    Deriving it reads concrete host values; jit-static callers (a traced
    ``tree``) must pass an explicit ``max_depth``.
    """
    if max_depth is None:
        n = int(tree.n_nodes)
        max_depth = (int(np.max(np.asarray(tree.node_depth)[:n])) + 1
                     if n else 1)
    x_bins = jnp.asarray(x_bins, jnp.int32)
    attr_is_cont = jnp.asarray(attr_is_cont, bool)
    node = jnp.zeros((x_bins.shape[0],), jnp.int32)
    heavy = heavy_child_table(tree.node_child0, tree.node_nchild,
                              tree.node_freq)

    def body(_, node):
        return descend_once(attr_is_cont, node, x_bins,
                            node_attr=tree.node_attr,
                            node_split_bin=tree.node_split_bin,
                            node_child0=tree.node_child0,
                            node_nchild=tree.node_nchild, heavy=heavy)

    node = jax.lax.fori_loop(0, max_depth, body, node)
    return tree.node_class[node]


def trees_equal(a: Tree, b: Tree, *, freq_tol: float = 1e-3) -> bool:
    """Structural equality of the live prefixes (host-side, for tests)."""
    a, b = a.to_numpy(), b.to_numpy()
    na, nb = int(a.n_nodes), int(b.n_nodes)
    if na != nb:
        return False
    for f in ("node_attr", "node_split_bin", "node_child0", "node_nchild",
              "node_class", "node_depth"):
        if not np.array_equal(getattr(a, f)[:na], getattr(b, f)[:na]):
            return False
    return bool(np.allclose(a.node_freq[:na], b.node_freq[:na],
                            atol=freq_tol, rtol=1e-4))
