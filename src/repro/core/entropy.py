"""C4.5 entropy / information-gain math, shared by every engine.

This module is the single source of truth for the split-scoring formulas of
the paper (Sect. 3.1, footnote 3):

    info(S)   = - sum_j  freq(c_j, S)/|S| * log2(freq(c_j, S)/|S|)
    gain(T, T_1..T_h) = info(T) - sum_i |T_i|/|T| * info(T_i)

with C4.5's unknown-value correction: frequencies are *weighted* counts over
cases with a known value for the tested attribute, and the gain is scaled by
the known fraction ``F = W_known / W_total``.

The same functions are called by

  * the sequential YaDT oracle (``core/c45.py``),
  * the vectorized frontier engine (``core/frontier.py``),
  * the Pallas kernel oracle (``kernels/ref.py``),

so that split decisions are bitwise comparable across engines (identical op
order on identical histogram tensors).

All functions are pure jnp, dtype-stable (float32 by default), and batched:
leading dimensions are arbitrary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# A weighted count below EPS_W is treated as an empty partition.
EPS_W = 1e-7
# Gains below EPS_GAIN are treated as "no information" (C4.5 uses a tiny
# positive epsilon so that FP noise never drives a split).
EPS_GAIN = 1e-6

NEG_INF = float("-inf")  # Python literal: safe to close over in Pallas kernels


def _xlogx(p: jnp.ndarray) -> jnp.ndarray:
    """x * log2(x), continuously extended with 0 at x == 0."""
    safe = jnp.where(p > 0, p, 1.0)
    return jnp.where(p > 0, p * (jnp.log2(safe)), 0.0)


def info(counts: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Entropy (bits) of a weighted class-count vector.

    ``info(S) = log2(W) - (1/W) * sum_c n_c log2 n_c`` with ``W = sum_c n_c``.
    Empty count vectors yield 0.  ``counts`` may have any leading batch shape.
    """
    counts = counts.astype(jnp.float32)
    w = jnp.sum(counts, axis=axis)
    safe_w = jnp.where(w > EPS_W, w, 1.0)
    s = jnp.sum(_xlogx(counts), axis=axis)
    ent = jnp.log2(safe_w) - s / safe_w
    return jnp.where(w > EPS_W, jnp.maximum(ent, 0.0), 0.0)


def weighted_info(counts: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """``W * info`` — the un-normalised entropy term ``W*log2(W) - sum n log n``.

    Summing ``weighted_info`` of children and dividing by the parent weight
    avoids one division per child and is the form used inside the kernels.
    """
    counts = counts.astype(jnp.float32)
    w = jnp.sum(counts, axis=axis)
    return jnp.maximum(_xlogx(w) - jnp.sum(_xlogx(counts), axis=axis), 0.0)


def split_gain_from_children(
    child_counts: jnp.ndarray,
    *,
    total_w: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Information gain of a partition.

    Args:
      child_counts: ``(..., H, C)`` weighted class counts per child.  The
        parent (known-valued) counts are the sum over ``H``.
      total_w: optional ``(...)`` total node weight *including* cases whose
        value for the attribute is unknown; the gain is scaled by the known
        fraction ``F = W_known / W_total`` (C4.5 unknown correction).  When
        None, ``F = 1``.

    Returns:
      ``(...)`` gain in bits (>= 0 up to FP noise).
    """
    parent = jnp.sum(child_counts, axis=-2)
    w_known = jnp.sum(parent, axis=-1)
    safe_w = jnp.where(w_known > EPS_W, w_known, 1.0)
    info_parent = weighted_info(parent)                       # W_k * info
    info_children = jnp.sum(weighted_info(child_counts), axis=-1)
    gain = (info_parent - info_children) / safe_w
    if total_w is not None:
        f = w_known / jnp.where(total_w > EPS_W, total_w, 1.0)
        gain = f * gain
    return jnp.where(w_known > EPS_W, jnp.maximum(gain, 0.0), 0.0)


def split_info(child_counts: jnp.ndarray) -> jnp.ndarray:
    """C4.5 split-info (denominator of the gain ratio) over children weights."""
    w_children = jnp.sum(child_counts, axis=-1)               # (..., H)
    return info(w_children, axis=-1)


def fayyad_irani_mask(hist: jnp.ndarray) -> jnp.ndarray:
    """Boundary-point candidate mask (YaDT's Fayyad–Irani optimisation).

    A cut between bins ``b`` and ``b+1`` can only maximise information gain
    at a *boundary point*: skip it when the nearest non-empty bin on each
    side is pure and both carry the same class (F&I 1992, Theorem 1 — the
    gain there is dominated by an adjacent boundary cut, so masking never
    changes the selected split; property-tested in tests/test_entropy.py).

    hist: (..., B, C) -> bool (..., B); True = evaluate the cut after bin b.
    """
    hist = hist.astype(jnp.float32)
    b_dim = hist.shape[-2]
    nonzero = jnp.sum(hist, -1) > EPS_W                     # (..., B)
    pure = jnp.sum((hist > EPS_W).astype(jnp.int32), -1) == 1
    cls = jnp.argmax(hist, -1)
    idx = jnp.arange(b_dim)

    # nearest non-empty bin at-or-before b / strictly-after b
    ax = nonzero.ndim - 1                 # lax.cummax rejects negative axes
    last = jax.lax.cummax(jnp.where(nonzero, idx, -1), axis=ax)
    nxt_rev = jax.lax.cummax(
        jnp.where(jnp.flip(nonzero, -1), idx, -1), axis=ax)
    at_or_after = (b_dim - 1) - jnp.flip(nxt_rev, -1)       # smallest i >= b
    nxt = jnp.concatenate(                                  # smallest i > b
        [at_or_after[..., 1:],
         jnp.full(at_or_after.shape[:-1] + (1,), b_dim,
                  at_or_after.dtype)], axis=-1)

    def take(a, i, fill):
        safe = jnp.clip(i, 0, b_dim - 1)
        v = jnp.take_along_axis(a, safe, axis=-1)
        return v, (i >= 0) & (i <= b_dim - 1)

    l_pure, l_ok = take(pure, last, False)
    l_cls, _ = take(cls, last, 0)
    r_pure, r_ok = take(pure, nxt, False)
    r_cls, _ = take(cls, nxt, 0)
    non_boundary = (l_ok & r_ok & l_pure & r_pure & (l_cls == r_cls))
    return ~non_boundary


def gains_for_continuous(
    hist: jnp.ndarray,
    *,
    total_w: jnp.ndarray,
    n_bins: jnp.ndarray,
    min_objs: float = 2.0,
    criterion: str = "gain",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Best binary split of a continuous attribute from its bin histogram.

    Scans every candidate threshold ``value <= edge[b]`` for ``b`` in
    ``[0, n_bins-2]`` — in EC4.5 rank space the bins *are* the sorted domain
    values of the whole training set, so the candidate set coincides with the
    C4.5 midpoint set and the selected edge is automatically "the greatest
    value of A in the whole training set below the local threshold"
    (paper §2.9-10 / EC4.5 binary search).

    Args:
      hist: ``(..., B, C)`` weighted (bin, class) counts of known-valued cases.
      total_w: ``(...)`` total node weight (for the F scaling).
      n_bins: ``(...)`` or scalar — actual number of bins of this attribute
        (bins >= n_bins are structural padding and must be empty).
      min_objs: C4.5 MINOBJS — both sides of a valid split must carry at
        least this much weight.
      criterion: ``"gain"`` (paper semantics) or ``"gain_ratio"``.

    Returns:
      ``best_score (...)`` (-inf when no valid candidate) and
      ``best_bin (...)`` int32 — the split is ``bin <= best_bin``.
    """
    hist = hist.astype(jnp.float32)
    b_dim = hist.shape[-2]
    left = jnp.cumsum(hist, axis=-2)                          # (..., B, C)
    known = left[..., -1, :]                                  # (..., C)
    right = known[..., None, :] - left                        # (..., B, C)

    w_known = jnp.sum(known, axis=-1)                         # (...)
    safe_w = jnp.where(w_known > EPS_W, w_known, 1.0)
    wl = jnp.sum(left, axis=-1)                               # (..., B)
    wr = jnp.sum(right, axis=-1)

    info_parent = weighted_info(known)                        # (...)
    info_lr = weighted_info(left) + weighted_info(right)      # (..., B)
    gain = (info_parent[..., None] - info_lr) / safe_w[..., None]
    f = w_known / jnp.where(total_w > EPS_W, total_w, 1.0)
    gain = f[..., None] * gain

    if criterion == "gain_ratio":
        denom = info(jnp.stack([wl, wr], axis=-1), axis=-1)
        gain = jnp.where(denom > EPS_W, gain / denom, 0.0)
    elif criterion != "gain":
        raise ValueError(f"unknown criterion: {criterion!r}")

    bins = jnp.arange(b_dim, dtype=jnp.int32)
    n_bins = jnp.asarray(n_bins, dtype=jnp.int32)
    structural = bins < jnp.expand_dims(n_bins - 1, -1) if n_bins.ndim else (
        bins < n_bins - 1)
    valid = structural & (wl >= min_objs) & (wr >= min_objs)
    score = jnp.where(valid, gain, NEG_INF)
    best_bin = jnp.argmax(score, axis=-1).astype(jnp.int32)   # first max
    best_score = jnp.max(score, axis=-1)
    return best_score, best_bin


def gains_for_discrete(
    hist: jnp.ndarray,
    *,
    total_w: jnp.ndarray,
    n_bins: jnp.ndarray,
    min_objs: float = 2.0,
    criterion: str = "gain",
) -> jnp.ndarray:
    """Score of the h-way split of a discrete attribute (one child per value).

    Valid only when at least two branches carry >= min_objs weight (C4.5).
    Returns ``(...)`` score, -inf when invalid.
    """
    hist = hist.astype(jnp.float32)
    b_dim = hist.shape[-2]
    bins = jnp.arange(b_dim, dtype=jnp.int32)
    n_bins = jnp.asarray(n_bins, dtype=jnp.int32)
    structural = bins < jnp.expand_dims(n_bins, -1) if n_bins.ndim else (
        bins < n_bins)
    hist = jnp.where(structural[..., None], hist, 0.0)

    gain = split_gain_from_children(hist, total_w=total_w)
    if criterion == "gain_ratio":
        denom = split_info(hist)
        gain = jnp.where(denom > EPS_W, gain / denom, 0.0)

    w_children = jnp.sum(hist, axis=-1)                       # (..., B)
    branches = jnp.sum((w_children >= min_objs).astype(jnp.int32), axis=-1)
    valid = branches >= 2
    return jnp.where(valid, gain, NEG_INF)


def gains_from_histogram(
    hist: jnp.ndarray,
    *,
    total_w: jnp.ndarray,
    attr_is_cont: jnp.ndarray,
    n_bins: jnp.ndarray,
    min_objs: float = 2.0,
    criterion: str = "gain",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-attribute best split score from a ``(..., A, B, C)`` histogram.

    This is the shared "splitAtt" (paper Fig. 3) evaluated for all attributes
    at once.  ``total_w`` broadcasts over the attribute axis; ``attr_is_cont``
    and ``n_bins`` are ``(A,)``.

    Returns ``(score, split_bin)`` of shape ``(..., A)``; ``split_bin`` is the
    threshold bin for continuous attributes and -1 for discrete ones.
    """
    tw = jnp.asarray(total_w)[..., None]                      # broadcast to A
    cont_score, cont_bin = gains_for_continuous(
        hist, total_w=tw, n_bins=n_bins, min_objs=min_objs, criterion=criterion)
    disc_score = gains_for_discrete(
        hist, total_w=tw, n_bins=n_bins, min_objs=min_objs, criterion=criterion)
    attr_is_cont = jnp.asarray(attr_is_cont, dtype=bool)
    score = jnp.where(attr_is_cont, cont_score, disc_score)
    split_bin = jnp.where(attr_is_cont, cont_bin, jnp.int32(-1))
    return score, split_bin


def pick_best_attribute(
    score: jnp.ndarray,
    active: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """splitPost argmax (paper §3.12): first attribute with the maximal score.

    Args:
      score: ``(..., A)`` per-attribute scores (-inf = invalid).
      active: ``(..., A)`` bool — attribute still active at the node (discrete
        attributes used by an ancestor are inactive, paper §2.6).

    Returns:
      ``(best_attr, best_score, has_split)`` — ``has_split`` requires a
      strictly positive score (no-gain nodes become leaves).
    """
    masked = jnp.where(active, score, NEG_INF)
    best_attr = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    best_score = jnp.max(masked, axis=-1)
    has_split = best_score > EPS_GAIN
    return best_attr, best_score, has_split
