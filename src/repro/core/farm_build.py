"""C4.5 tree growth through the supervised threaded farm (paper Fig. 5).

This is the paper's actual deployment shape — ``ff_farm<ws_scheduler>`` with
the emitter feeding node tasks to workers over the feedback channel — run on
the fault-tolerant :class:`repro.core.farm.Farm`:

  * **workers** execute :func:`repro.core.c45.split_node`, a *pure* function
    of (dataset, task).  Attempts are therefore idempotent: the supervisor
    may re-run a crashed/hung/lost task on any surviving worker without
    corrupting the build;
  * the **emitter** owns the node table and applies split decisions
    strictly in task-emission (= breadth-first) order, buffering
    out-of-order completions.  Child node ids are thus assigned in exactly
    the sequential oracle's BFS order no matter how the farm interleaves —
    trees are elementwise-comparable (``trees_equal``) even under injected
    crashes, worker deaths and retries.

A task that exhausts its :class:`~repro.core.farm.FaultPolicy` retry budget
is quarantined; its node degrades to a leaf (the tree stays valid) and
``strict=True`` (default) raises so silent truncation cannot pass for
success.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np

from repro.core import c45
from repro.core.binning import BinnedDataset
from repro.core.config import GrowConfig
from repro.core.farm import Farm, FaultPolicy, TaskFailure
from repro.core.scheduler import Policy
from repro.core.tree import Tree


@dataclasses.dataclass
class NodeTask:
    """One farm task = one open node (weight = r cases, the WS weight)."""

    node_id: int
    idx: np.ndarray
    w: np.ndarray
    active: np.ndarray
    depth: int
    cls: int
    freq: np.ndarray


class QuarantinedNodes(RuntimeError):
    """Raised under ``strict=True`` when node tasks exhausted their retries."""

    def __init__(self, failures: list[TaskFailure]):
        self.failures = failures
        ids = [f.payload.node_id for f in failures]
        super().__init__(f"{len(failures)} node task(s) quarantined: {ids}")


def build(ds: BinnedDataset, cfg: GrowConfig = GrowConfig(), *,
          n_workers: int = 4, policy: Policy | None = None,
          fault: FaultPolicy | None = None, injector: Any = None,
          capacity: int | None = None, strict: bool = True,
          stats_out: dict | None = None, tracer: Any = None,
          metrics: Any = None, attr_mask: np.ndarray | None = None,
          case_w: np.ndarray | None = None) -> Tree:
    """Grow a C4.5 tree through the supervised farm; oracle-equal result.

    ``injector``  — optional :class:`repro.core.faults.FaultInjector`; its
                    ``wrap_worker`` is applied to the node-split service.
    ``stats_out`` — optional dict filled with the farm's execution + failure
                    breakdown (``Farm.stats()``).
    ``tracer`` / ``metrics`` — optional :class:`repro.obs.trace.Tracer` /
                    :class:`repro.obs.metrics.Registry`; the farm records
                    task spans, retry/quarantine/death events and
                    queued-weight timelines into them.
    ``attr_mask`` / ``case_w`` — same per-tree feature-subset / bootstrap
                    weight hooks as :func:`repro.core.c45.build`.
    """
    nodes = c45._Nodes.new()
    order: deque[int] = deque()        # emission (= BFS) order, apply cursor
    ready: dict[int, c45.SplitDecision] = {}
    depth_of: dict[int, int] = {}
    quarantined: list[TaskFailure] = []

    def make_task(nid: int, idx, w, active) -> NodeTask:
        return NodeTask(node_id=nid, idx=idx, w=w, active=active,
                        depth=depth_of[nid], cls=int(nodes.cls[nid]),
                        freq=nodes.freq[nid])

    def apply_ready(send) -> None:
        """splitPost in emission order: ids match the sequential oracle."""
        while order and order[0] in ready:
            nid = order.popleft()
            dec = ready.pop(nid)
            if dec.is_leaf:
                continue
            nodes.attr[nid] = dec.attr
            nodes.split_bin[nid] = dec.split_bin
            nodes.nchild[nid] = dec.n_children
            first = None
            for j in range(dec.n_children):
                cid = nodes.add(cls=dec.child_cls[j], freq=dec.child_freq[j],
                                depth=depth_of[nid] + 1)
                depth_of[cid] = depth_of[nid] + 1
                if first is None:
                    first = cid
                order.append(cid)
                t = make_task(cid, dec.child_idx[j], dec.child_w[j],
                              dec.child_active)
                send(t, weight=float(max(len(t.idx), 1)))
            nodes.child0[nid] = first

    def emitter(task: Any, send) -> None:
        if task is None:                       # start-up: emit the root
            n = ds.n_cases
            root_idx = np.arange(n, dtype=np.int64)
            w_base = ds.w if case_w is None else np.asarray(case_w)
            root_w = w_base.astype(np.float32).copy()
            root_active = (np.ones(ds.n_attrs, dtype=bool)
                           if attr_mask is None
                           else np.asarray(attr_mask, dtype=bool).copy())
            root_freq = c45.class_frequencies(ds, root_idx, root_w)
            root = nodes.add(cls=int(np.argmax(root_freq)), freq=root_freq,
                             depth=0)
            depth_of[root] = 0
            order.append(root)
            send(make_task(root, root_idx, root_w, root_active),
                 weight=float(n))
            return
        if isinstance(task, TaskFailure):      # quarantined: degrade to leaf
            quarantined.append(task)
            ready[task.payload.node_id] = c45.SplitDecision()
        else:
            nid, dec = task
            ready[nid] = dec
        apply_ready(send)

    def worker(t: NodeTask):
        return t.node_id, c45.split_node(
            ds, cfg, idx=t.idx, w=t.w, active=t.active, depth=t.depth,
            freq=t.freq, cls=t.cls)

    farm = Farm(n_workers, policy=policy, fault=fault, tracer=tracer,
                metrics=metrics)
    svc = injector.wrap_worker(worker) if injector is not None else worker
    stats = farm.run(emitter, svc)
    if stats_out is not None:
        stats_out.update(stats)
    if strict and quarantined:
        raise QuarantinedNodes(quarantined)
    return nodes.finish(ds.n_classes, capacity)
