"""Shared configuration for the tree-growing engines."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GrowConfig:
    """Parameters of the C4.5 growth phase (paper Sect. 3.1).

    Attributes:
      min_objs: C4.5 MINOBJS — a node needs weight >= 2*min_objs to split and
        each side of a continuous split needs weight >= min_objs.
      criterion: "gain" (paper footnote 3) or "gain_ratio" (full C4.5).
      max_depth: safety bound on tree depth.
      max_nodes: tree array capacity (frontier engine; oracle grows freely).
      frontier_slots: K — max nodes processed per superstep by the frontier
        engine (the batched analogue of the farm's in-flight task window).
      unknown_fractional: True = full C4.5 semantics, unknown-valued cases go
        to every child with rebalanced weights (sequential oracle only);
        False = route unknowns to the heaviest child (fixed-shape SPMD rule,
        see DESIGN.md §2).
      cost_model: buildAttTest variant for NP/NAP switching: "nsq" (|T|<c·r²,
        paper's best), "nlogn" (|T|<c·r·log r), "alpha" (α<r).
      alpha: the α of the "alpha" cost model (paper uses 1000).
      strategy: "np" (nodes parallelism) or "nap" (nodes+attributes).
      compact: ``impl="pallas"`` only — gather live cases into bucketed
        dense buffers before the histogram kernel, so deep supersteps cost
        O(live) instead of O(N) (see repro.kernels.compaction).
      compact_min_bucket: smallest gather bucket of the power-of-two ladder
        (below this the gather overhead beats the kernel-traffic saving).
      block_t/block_k/block_b/block_a: pinned Pallas tile sizes for the
        histogram (t=case, k=slot, b=bin) and split-gain (k=slot, a=attr)
        kernels; None = shape-driven heuristic (repro.kernels.autotune).
    """

    min_objs: float = 2.0
    criterion: str = "gain"
    max_depth: int = 64
    max_nodes: int = 1 << 15
    frontier_slots: int = 256
    unknown_fractional: bool = False
    cost_model: str = "nsq"
    alpha: float = 1000.0
    strategy: str = "nap"
    compact: bool = True
    compact_min_bucket: int = 1024
    block_t: int | None = None
    block_k: int | None = None
    block_b: int | None = None
    block_a: int | None = None
