"""The paper's contribution: farm-parallel C4.5 decision-tree induction.

Public surface:

  binning.fit / BinnedDataset   — EC4.5 rank-space representation
  c45.build                     — sequential YaDT oracle (reference semantics)
  frontier.build                — SPMD level-synchronous engine (NP/NAP)
  GrowConfig                    — growth parameters incl. cost model/strategy
  farm.Farm, scheduler.*        — farm-with-feedback + DRR/OD/WS policies
  simulate.simulate             — discrete-event farm replay (paper figures)
"""

from repro.core.binning import BinnedDataset, fit, from_binned  # noqa: F401
from repro.core.config import GrowConfig  # noqa: F401
from repro.core.tree import Tree, predict, trees_equal  # noqa: F401
