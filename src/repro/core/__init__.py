"""The paper's contribution: farm-parallel C4.5 decision-tree induction.

Public surface:

  binning.fit / BinnedDataset   — EC4.5 rank-space representation
  c45.build                     — sequential YaDT oracle (reference semantics)
  frontier.build                — SPMD level-synchronous engine (NP/NAP)
  frontier.build_farm           — fault-tolerant threaded-farm build
  GrowConfig                    — growth parameters incl. cost model/strategy
  farm.Farm, FaultPolicy        — supervised farm-with-feedback runtime
  faults.FaultInjector          — deterministic crash/hang/slow injection
  scheduler.*                   — DRR/OD/WS/HealthWS policies
  simulate.simulate             — discrete-event farm replay (paper figures)
"""

from repro.core.binning import BinnedDataset, fit, from_binned  # noqa: F401
from repro.core.config import GrowConfig  # noqa: F401
from repro.core.farm import (AllWorkersDead, Farm, FaultPolicy,  # noqa: F401
                             TaskFailure, WorkerCrashed)
from repro.core.tree import Tree, predict, trees_equal  # noqa: F401
