"""YaDT-FF on SPMD hardware: level-synchronous frontier tree growth.

This is the TPU-native adaptation of the paper's farm-with-feedback (see
DESIGN.md §2).  The farm's task stream becomes a *frontier* of open nodes,
drained in batches of K = ``GrowConfig.frontier_slots`` per **superstep**:

  splitPre   -> batched stop tests on stored node frequencies
  splitAtt   -> one fused (node, attr, bin, class) histogram + gain pass
                (the attribute axis is the NAP sharding axis)
  splitPost  -> batched argmax / child allocation / case re-routing
                (the synchronisation point that closes the superstep)

Because open nodes are selected in ascending id order and children are
allocated contiguously in slot order, node ids coincide exactly with the
sequential oracle's breadth-first ids — trees are comparable elementwise.

Everything is fixed-shape and jit-able; the full build is a
``lax.while_loop`` over supersteps.  The same tree can also be grown
host-side through the supervised threaded farm — :func:`build_farm` — which
tolerates worker crashes/hangs/deaths (:mod:`repro.core.farm_build`) and
stays elementwise-equal to both this engine and the sequential oracle.
The splitAtt hot-spot is pluggable:
``impl="jnp"`` scores gains from a segment-sum histogram (reference);
``impl="pallas"`` runs the whole phase on the kernels in
:mod:`repro.kernels` — the MXU one-hot-matmul histogram (with bucketed
active-case compaction, ``GrowConfig.compact``) feeding the fused
scan/entropy split-gain kernel, tile sizes planned by
:mod:`repro.kernels.autotune`.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_models, entropy
from repro.core.binning import BinnedDataset
from repro.core.config import GrowConfig
from repro.core.tree import Tree

EPS_W = entropy.EPS_W


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GrowState:
    tree: Tree
    status: jnp.ndarray      # int32 (M,): 0 empty, 1 open, 2 internal, 3 leaf
    active: jnp.ndarray      # bool (M, A): attributes active at each node
    case_node: jnp.ndarray   # int32 (N,): current node of each case
    n_nodes: jnp.ndarray     # int32 scalar
    overflow: jnp.ndarray    # bool scalar — capacity forced early leaves

    STATUS_EMPTY = 0
    STATUS_OPEN = 1
    STATUS_INTERNAL = 2
    STATUS_LEAF = 3


@dataclasses.dataclass(frozen=True)
class FrontierProblem:
    """Static description of one growth problem (shapes are jit constants)."""
    n_cases: int
    n_attrs: int
    n_bins_max: int          # B: histogram bins (padded)
    n_classes: int
    max_children: int        # H: >= 2 and >= widest discrete split
    cfg: GrowConfig

    @staticmethod
    def from_dataset(ds: BinnedDataset, cfg: GrowConfig) -> "FrontierProblem":
        disc = ds.n_bins[~ds.attr_is_cont]
        h = max(2, int(disc.max()) if disc.size else 2)
        return FrontierProblem(
            n_cases=ds.n_cases, n_attrs=ds.n_attrs,
            n_bins_max=max(1, ds.max_bins), n_classes=ds.n_classes,
            max_children=h, cfg=cfg)


def init_state(prob: FrontierProblem, y: jnp.ndarray, w: jnp.ndarray,
               attr_mask: jnp.ndarray | None = None) -> GrowState:
    cfg = prob.cfg
    tree = Tree.empty(cfg.max_nodes, prob.n_classes)
    root_freq = jax.ops.segment_sum(w.astype(jnp.float32), y,
                                    num_segments=prob.n_classes)
    tree.node_freq = tree.node_freq.at[0].set(root_freq)
    tree.node_class = tree.node_class.at[0].set(
        jnp.argmax(root_freq).astype(jnp.int32))
    active = jnp.ones((cfg.max_nodes, prob.n_attrs), bool)
    if attr_mask is not None:
        active = active & jnp.asarray(attr_mask, bool)[None, :]
    return GrowState(
        tree=tree,
        status=jnp.zeros((cfg.max_nodes,), jnp.int32).at[0].set(
            GrowState.STATUS_OPEN),
        active=active,
        case_node=jnp.zeros((prob.n_cases,), jnp.int32),
        n_nodes=jnp.int32(1),
        overflow=jnp.bool_(False),
    )


# --------------------------------------------------------------------------
# Histogram pass ("splitAtt" data collection)
# --------------------------------------------------------------------------

def frontier_histogram_jnp(
    x: jnp.ndarray,            # int32 (N, A), -1 = unknown
    y: jnp.ndarray,            # int32 (N,)
    w: jnp.ndarray,            # f32 (N,)
    slot: jnp.ndarray,         # int32 (N,), -1 = not participating
    *, n_slots: int, n_bins: int, n_classes: int,
) -> jnp.ndarray:
    """(K, A, B+1, C) weighted counts; bin index B collects unknown values.

    Reference implementation: one flat segment-sum.  The Pallas kernel
    (:mod:`repro.kernels.histogram`) computes the same tensor with MXU
    one-hot matmuls and VMEM-tiled accumulation.
    """
    n, a_dim = x.shape
    k, b, c = n_slots, n_bins, n_classes
    slot_safe = jnp.where(slot >= 0, slot, k)                 # dump row
    bin_safe = jnp.where(x >= 0, x, b)                        # unknown bin
    flat = ((slot_safe[:, None] * a_dim + jnp.arange(a_dim)[None, :])
            * (b + 1) + bin_safe) * c + y[:, None]
    hist = jax.ops.segment_sum(
        jnp.broadcast_to(w[:, None], (n, a_dim)).reshape(-1),
        flat.reshape(-1),
        num_segments=(k + 1) * a_dim * (b + 1) * c)
    return hist.reshape(k + 1, a_dim, b + 1, c)[:k]


def _block_plan(prob: FrontierProblem, n_cases: int):
    from repro.kernels import autotune
    return autotune.plan_for_config(
        prob.cfg, n_cases=n_cases, n_bins=prob.n_bins_max,
        n_classes=prob.n_classes, n_attrs=prob.n_attrs)


def _histogram(x, y, w, slot, *, prob: FrontierProblem, impl: str):
    k = prob.cfg.frontier_slots
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops
        plan = _block_plan(prob, prob.n_cases)
        if prob.cfg.compact:
            return kernel_ops.frontier_histogram_compact(
                x, y, w, slot, n_slots=k, n_bins=prob.n_bins_max,
                n_classes=prob.n_classes,
                min_bucket=prob.cfg.compact_min_bucket,
                block_t=plan.block_t, block_k=plan.block_k,
                block_b=plan.block_b)
        return kernel_ops.frontier_histogram(
            x, y, w, slot, n_slots=k, n_bins=prob.n_bins_max,
            n_classes=prob.n_classes, block_t=plan.block_t,
            block_k=plan.block_k, block_b=plan.block_b)
    return frontier_histogram_jnp(
        x, y, w, slot, n_slots=k, n_bins=prob.n_bins_max,
        n_classes=prob.n_classes)


def _gains(hist, total_w, attr_is_cont, n_bins, *, prob: FrontierProblem,
           impl: str):
    """splitAtt scoring: (K, A) score/bin planes from the (K, A, B, C) hist.

    ``impl="pallas"`` runs the fused scan/entropy kernel — one HBM read of
    the histogram, results bit-identical to the jnp path (the kernel body
    calls the same :mod:`repro.core.entropy` functions per VMEM block, and
    the (K, A) grid decomposition is exact for per-(node, attr) math).
    """
    cfg = prob.cfg
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops
        plan = _block_plan(prob, prob.n_cases)
        return kernel_ops.split_gain(
            hist, total_w, attr_is_cont, n_bins, min_objs=cfg.min_objs,
            criterion=cfg.criterion, block_k=plan.block_k,
            block_a=plan.block_a)
    return entropy.gains_from_histogram(
        hist, total_w=total_w, attr_is_cont=attr_is_cont, n_bins=n_bins,
        min_objs=cfg.min_objs, criterion=cfg.criterion)


# --------------------------------------------------------------------------
# One superstep = splitPre + splitAtt + splitPost over K open nodes.
# The phases are separate jit-able functions so the observability path
# (build(collect_stats=True, tracer=...)) can time each one; ``superstep``
# composes them and is what the fused whole-build while_loop traces.
# --------------------------------------------------------------------------

def split_pre(state: GrowState, *, prob: FrontierProblem
              ) -> dict[str, jnp.ndarray]:
    """Frontier selection + stop tests on stored node frequencies."""
    cfg = prob.cfg
    m = cfg.max_nodes
    k = cfg.frontier_slots
    tree = state.tree

    # ---- select up to K open nodes, FIFO by id (= breadth-first) ----------
    ids = jnp.nonzero(state.status == GrowState.STATUS_OPEN,
                      size=k, fill_value=m)[0].astype(jnp.int32)
    valid = ids < m
    ids_safe = jnp.minimum(ids, m - 1)

    node_to_slot = jnp.full((m + 1,), -1, jnp.int32).at[ids].set(
        jnp.arange(k, dtype=jnp.int32), mode="drop")
    slot = node_to_slot[state.case_node]                      # (N,)

    # ---- stop tests on stored frequencies ----------------------------------
    freq = jnp.where(valid[:, None], tree.node_freq[ids_safe], 0.0)  # (K, C)
    total_w = jnp.sum(freq, axis=-1)
    depth_k = tree.node_depth[ids_safe]
    pure = jnp.sum((freq > EPS_W).astype(jnp.int32), -1) <= 1
    small = total_w < 2.0 * cfg.min_objs
    deep = depth_k >= cfg.max_depth
    pre_leaf = pure | small | deep
    return dict(ids=ids, valid=valid, ids_safe=ids_safe, slot=slot,
                total_w=total_w, depth_k=depth_k, pre_leaf=pre_leaf)


def split_att(state: GrowState, pre: dict,
              x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
              attr_is_cont: jnp.ndarray, n_bins: jnp.ndarray,
              *, prob: FrontierProblem, impl: str) -> dict[str, jnp.ndarray]:
    """The hot phase: fused histogram + gain over (node, attribute)."""
    b_dim = prob.n_bins_max
    from repro.sharding.act import shard_frontier_hist
    hist_u = shard_frontier_hist(
        _histogram(x, y, w, pre["slot"], prob=prob, impl=impl))  # (K,A,B+1,C)
    hist = hist_u[:, :, :b_dim, :]
    unknown = hist_u[:, :, b_dim, :]                          # (K, A, C)
    score, split_bin = _gains(
        hist, pre["total_w"], attr_is_cont, n_bins,
        prob=prob, impl=impl)                                 # (K, A)
    active_k = state.active[pre["ids_safe"]] & pre["valid"][:, None]
    best_attr, best_score, has_split = entropy.pick_best_attribute(
        score, active_k)
    return dict(hist=hist, unknown=unknown, split_bin=split_bin,
                active_k=active_k, best_attr=best_attr, has_split=has_split)


def split_post(state: GrowState, pre: dict, att: dict,
               x: jnp.ndarray, attr_is_cont: jnp.ndarray,
               n_bins: jnp.ndarray, *, prob: FrontierProblem,
               ) -> tuple[GrowState, dict[str, jnp.ndarray]]:
    """Argmax done: allocate children, scatter results, route cases."""
    cfg = prob.cfg
    m = cfg.max_nodes
    k = cfg.frontier_slots
    a_dim, c_dim, h_dim = prob.n_attrs, prob.n_classes, prob.max_children
    tree = state.tree
    ids, valid, ids_safe = pre["ids"], pre["valid"], pre["ids_safe"]
    slot, total_w, depth_k = pre["slot"], pre["total_w"], pre["depth_k"]
    hist, unknown, active_k = att["hist"], att["unknown"], att["active_k"]
    best_attr = att["best_attr"]

    internal = valid & ~pre["pre_leaf"] & att["has_split"]
    is_cont = attr_is_cont[best_attr]
    sb = jnp.take_along_axis(att["split_bin"], best_attr[:, None], 1)[:, 0]
    nch_attr = jnp.where(is_cont, 2, n_bins[best_attr]).astype(jnp.int32)
    nch = jnp.where(internal, nch_attr, 0)

    # capacity check: if this superstep would overflow, force leaves instead
    overflow = state.n_nodes + jnp.sum(nch) > m
    internal = internal & ~overflow
    nch = jnp.where(overflow, 0, nch)
    total_children = jnp.sum(nch)
    child0 = state.n_nodes + jnp.cumsum(nch) - nch            # exclusive

    # child class frequencies (K, H, C)
    hist_best = jnp.take_along_axis(
        hist, best_attr[:, None, None, None], axis=1)[:, 0]   # (K, B, C)
    csum = jnp.cumsum(hist_best, axis=1)
    left = jnp.take_along_axis(
        csum, jnp.maximum(sb, 0)[:, None, None], axis=1)[:, 0]  # (K, C)
    known = csum[:, -1, :]
    right = known - left
    cont_freq = jnp.concatenate(
        [jnp.stack([left, right], axis=1),
         jnp.zeros((k, h_dim - 2, c_dim), jnp.float32)], axis=1)
    disc_freq = hist_best[:, :h_dim, :]
    disc_mask = (jnp.arange(h_dim)[None, :] < nch_attr[:, None])
    disc_freq = jnp.where(disc_mask[:, :, None], disc_freq, 0.0)
    child_freq = jnp.where(is_cont[:, None, None], cont_freq, disc_freq)

    # unknown-valued cases go to the heaviest child (DESIGN.md §2)
    unk = jnp.take_along_axis(unknown, best_attr[:, None, None],
                              axis=1)[:, 0]                   # (K, C)
    child_w = jnp.sum(child_freq, axis=-1)                    # (K, H)
    in_range = jnp.arange(h_dim)[None, :] < jnp.maximum(nch_attr, 1)[:, None]
    heaviest = jnp.argmax(jnp.where(in_range, child_w, -jnp.inf),
                          axis=-1).astype(jnp.int32)          # (K,)
    child_freq = child_freq + (
        jax.nn.one_hot(heaviest, h_dim, dtype=jnp.float32)[:, :, None]
        * unk[:, None, :])

    parent_class = tree.node_class[ids_safe]
    cw = jnp.sum(child_freq, axis=-1)
    child_class = jnp.where(cw > EPS_W,
                            jnp.argmax(child_freq, axis=-1),
                            parent_class[:, None]).astype(jnp.int32)

    # ---- scatter node results ----------------------------------------------
    write_ids = jnp.where(valid, ids, m)                      # m = dropped
    tree = dataclasses.replace(
        tree,
        node_attr=tree.node_attr.at[write_ids].set(
            jnp.where(internal, best_attr, -1), mode="drop"),
        node_split_bin=tree.node_split_bin.at[write_ids].set(
            jnp.where(internal & is_cont, sb, -1), mode="drop"),
        node_child0=tree.node_child0.at[write_ids].set(
            jnp.where(internal, child0, 0), mode="drop"),
        node_nchild=tree.node_nchild.at[write_ids].set(nch, mode="drop"),
    )
    status = state.status.at[write_ids].set(
        jnp.where(internal, GrowState.STATUS_INTERNAL, GrowState.STATUS_LEAF),
        mode="drop")

    # ---- scatter children ---------------------------------------------------
    j = jnp.arange(h_dim, dtype=jnp.int32)[None, :]           # (1, H)
    child_ids = child0[:, None] + j                           # (K, H)
    child_live = internal[:, None] & (j < nch[:, None])
    cids = jnp.where(child_live, child_ids, m)
    tree = dataclasses.replace(
        tree,
        node_class=tree.node_class.at[cids.reshape(-1)].set(
            child_class.reshape(-1), mode="drop"),
        node_freq=tree.node_freq.at[cids.reshape(-1)].set(
            child_freq.reshape(-1, c_dim), mode="drop"),
        node_depth=tree.node_depth.at[cids.reshape(-1)].set(
            jnp.broadcast_to(depth_k[:, None] + 1, (k, h_dim)).reshape(-1),
            mode="drop"),
    )
    status = status.at[cids.reshape(-1)].set(GrowState.STATUS_OPEN,
                                             mode="drop")
    child_active = state.active[ids_safe]                     # (K, A)
    child_active = child_active & ~(
        (~is_cont)[:, None]
        & (jnp.arange(a_dim)[None, :] == best_attr[:, None]))
    active = state.active.at[cids.reshape(-1)].set(
        jnp.broadcast_to(child_active[:, None, :],
                         (k, h_dim, a_dim)).reshape(-1, a_dim), mode="drop")

    # ---- route cases to their child (the feedback edge) --------------------
    part = slot >= 0
    slot_safe = jnp.maximum(slot, 0)
    a_case = best_attr[slot_safe]
    # Row-local select of x[i, a_case[i]].  A take_along_axis here makes the
    # SPMD partitioner materialise replicated (N, 1, 2) gather indices plus
    # an all-reduce of the result — 120 MB/superstep of pure routing traffic
    # (measured).  The one-hot contraction is elementwise row-local: zero
    # collectives, A x s32 reads (A = 9).
    onehot_a = (jnp.arange(a_dim, dtype=jnp.int32)[None, :]
                == a_case[:, None])
    b_case = jnp.sum(jnp.where(onehot_a, x, 0), axis=1)
    j_cont = jnp.where(b_case <= sb[slot_safe], 0, 1)
    j_case = jnp.where(is_cont[slot_safe], j_cont, b_case)
    j_case = jnp.where(b_case < 0, heaviest[slot_safe], j_case)
    new_node = child0[slot_safe] + j_case
    case_node = jnp.where(part & internal[slot_safe], new_node,
                          state.case_node).astype(jnp.int32)

    new_state = GrowState(
        tree=dataclasses.replace(tree, n_nodes=state.n_nodes + total_children),
        status=status, active=active, case_node=case_node,
        n_nodes=state.n_nodes + total_children,
        overflow=state.overflow | overflow,
    )
    stats = dict(
        n_processed=jnp.sum(valid.astype(jnp.int32)),
        n_active=jnp.sum((slot >= 0).astype(jnp.int32)),
        n_internal=jnp.sum(internal.astype(jnp.int32)),
        n_children=total_children,
        max_r=jnp.max(jnp.where(valid, total_w, 0.0)),
        nap_nodes=jnp.sum(cost_models.build_att_test(
            cfg.cost_model, n_total_cases=float(prob.n_cases),
            r=total_w, c=jnp.sum(active_k, -1).astype(jnp.float32),
            alpha=cfg.alpha).astype(jnp.int32) * valid.astype(jnp.int32)),
    )
    return new_state, stats


def superstep(
    state: GrowState,
    x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
    attr_is_cont: jnp.ndarray, n_bins: jnp.ndarray,
    *, prob: FrontierProblem, impl: str = "jnp",
) -> tuple[GrowState, dict[str, jnp.ndarray]]:
    """One fused superstep: splitPre → splitAtt → splitPost."""
    pre = split_pre(state, prob=prob)
    att = split_att(state, pre, x, y, w, attr_is_cont, n_bins,
                    prob=prob, impl=impl)
    return split_post(state, pre, att, x, attr_is_cont, n_bins, prob=prob)


# --------------------------------------------------------------------------
# Full build
# --------------------------------------------------------------------------

def _superstep_fn(prob: FrontierProblem, impl: str):
    def fn(state, x, y, w, attr_is_cont, n_bins):
        return superstep(state, x, y, w, attr_is_cont, n_bins,
                         prob=prob, impl=impl)
    return fn


@functools.partial(jax.jit, static_argnames=("prob", "impl"))
def _build_jit(x, y, w, attr_mask, attr_is_cont, n_bins, *,
               prob: FrontierProblem, impl: str) -> GrowState:
    state = init_state(prob, y, w, attr_mask)
    step = _superstep_fn(prob, impl)

    def cond(state):
        return jnp.any(state.status == GrowState.STATUS_OPEN)

    def body(state):
        new_state, _ = step(state, x, y, w, attr_is_cont, n_bins)
        return new_state

    return jax.lax.while_loop(cond, body, state)


def build(ds: BinnedDataset, cfg: GrowConfig = GrowConfig(), *,
          impl: str = "jnp", collect_stats: bool = False,
          tracer: Any = None, metrics: Any = None,
          attr_mask: Any = None, case_w: Any = None,
          ) -> Tree | tuple[Tree, list[dict[str, Any]]]:
    """Grow a C4.5 tree with the SPMD frontier engine.

    With ``collect_stats=True`` the superstep loop runs host-side and returns
    per-superstep scheduling statistics (NP vs NAP decisions per the
    configured cost model — the data behind paper Fig. 15); the per-step
    ``n_active``/``nap_nodes``/... values also flow into the metrics
    registry (``metrics``, default the process-wide one).

    With an *enabled* ``tracer`` (:class:`repro.obs.trace.Tracer`) the loop
    additionally runs the three phases as separately jitted, synchronously
    timed steps, so the exported trace shows real splitPre / splitAtt /
    splitPost wall time per superstep.  With tracing disabled nothing
    changes: the fused single-jit superstep (or the whole-build
    ``while_loop``) runs exactly as before.

    ``attr_mask`` (bool (A,)) restricts the split search to a subset of
    attributes; ``case_w`` (f32 (N,)) overrides the per-case weights — the
    ensemble trainer's per-tree hooks (:mod:`repro.ensemble`).  Both are
    traced arguments, so forests of masked/bootstrapped trees reuse one
    compiled build.
    """
    if cfg.unknown_fractional:
        raise ValueError("frontier engine routes unknowns to the heaviest "
                         "child; use the c45 oracle for fractional semantics")
    prob = FrontierProblem.from_dataset(ds, cfg)
    x = jnp.asarray(ds.x)
    y = jnp.asarray(ds.y)
    w = jnp.asarray(ds.w if case_w is None else case_w, jnp.float32)
    mask = (jnp.ones((ds.n_attrs,), bool) if attr_mask is None
            else jnp.asarray(attr_mask, bool))
    cont = jnp.asarray(ds.attr_is_cont)
    nb = jnp.asarray(ds.n_bins, jnp.int32)
    traced = tracer is not None and tracer.enabled

    if not collect_stats and not traced:
        state = _build_jit(x, y, w, mask, cont, nb, prob=prob, impl=impl)
        return dataclasses.replace(state.tree, n_nodes=state.n_nodes)

    from repro.obs import metrics as obs_metrics
    reg = metrics if metrics is not None else obs_metrics.REGISTRY
    m_steps = reg.counter("frontier_supersteps_total")
    m_active = reg.gauge("frontier_active_cases")
    m_open = reg.gauge("frontier_open_nodes")
    m_nap = reg.counter("frontier_nap_nodes_total")
    m_children = reg.counter("frontier_children_total")
    m_phase = reg.histogram("frontier_phase_seconds",
                            "per-phase superstep wall time, phase= label")

    if traced:
        pre_j = jax.jit(functools.partial(split_pre, prob=prob))
        att_j = jax.jit(functools.partial(split_att, prob=prob, impl=impl))
        post_j = jax.jit(functools.partial(split_post, prob=prob))

        def timed_phase(name, fn, *args):
            t0 = time.perf_counter()
            with tracer.span(name):
                out = jax.block_until_ready(fn(*args))
            m_phase.observe(time.perf_counter() - t0, phase=name)
            return out

        def step_fn(state, step_i):
            with tracer.span("superstep", step=step_i):
                pre = timed_phase("splitPre", pre_j, state)
                att = timed_phase("splitAtt", att_j, state, pre,
                                  x, y, w, cont, nb)
                return timed_phase("splitPost", post_j, state, pre, att,
                                   x, cont, nb)
    else:
        fused = jax.jit(_superstep_fn(prob, impl))

        def step_fn(state, step_i):
            return fused(state, x, y, w, cont, nb)

    state = init_state(prob, y, w, mask)
    out: list[dict[str, Any]] = []
    step_i = 0
    while bool(jnp.any(state.status == GrowState.STATUS_OPEN)):
        state, stats = step_fn(state, step_i)
        row = {k: np.asarray(v).item() for k, v in stats.items()}
        out.append(row)
        m_steps.inc()
        m_active.set(row["n_active"])
        m_open.set(row["n_processed"])
        m_nap.inc(row["nap_nodes"])
        m_children.inc(row["n_children"])
        if traced:
            tracer.counter("frontier.n_active", value=row["n_active"])
        step_i += 1
    tree = dataclasses.replace(state.tree, n_nodes=state.n_nodes)
    if not collect_stats:
        return tree
    return tree, out


def build_farm(ds: BinnedDataset, cfg: GrowConfig = GrowConfig(), **kw):
    """Grow the same tree through the supervised *threaded* farm.

    The host-side, fault-tolerant counterpart of :func:`build`: workers may
    crash, hang past ``FaultPolicy.task_deadline`` or die permanently and
    the result is still elementwise-equal to the oracle (and hence to the
    SPMD engine).  See :func:`repro.core.farm_build.build` for the keyword
    surface (``n_workers``, ``fault``, ``injector``, ``policy``, ...).
    """
    from repro.core import farm_build
    return farm_build.build(ds, cfg, **kw)
