"""EC4.5 rank-space data representation (paper Sect. 3.2).

EC4.5/YaDT store each continuous value as an *index into the pre-sorted
attribute domain* computed once over the whole training set.  That makes the
per-node threshold search a pure integer problem and the final threshold
lookup ("the greatest value of A in the whole training set below the local
threshold", paper §2.9-10) an O(log d) binary search — here it is a
precomputed table lookup, because bin b's edge *is* that greatest value.

``fit`` produces a :class:`BinnedDataset`:

  * continuous attribute with ``|domain| <= max_bins``  →  **exact** rank
    space; bin b == the b-th smallest known value; C4.5 semantics preserved
    bit-for-bit.
  * continuous attribute with more distinct values      →  quantile bins
    (the RainForest/counting-sort regime EC4.5 switches to on narrow ranges,
    here made global); the approximation is confined to this module.
  * discrete attribute  →  bins are the category codes themselves.

Unknown values (NaN for continuous, negative codes for discrete) map to
bin -1 and carry C4.5 weighted-case semantics downstream.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

UNKNOWN = -1


@dataclasses.dataclass(frozen=True)
class BinnedDataset:
    """Columnar training set in rank space.  All engines consume this."""

    x: np.ndarray              # int32 (N, A); -1 = unknown
    y: np.ndarray              # int32 (N,) class labels in [0, n_classes)
    w: np.ndarray              # float32 (N,) case weights (C4.5 weighted cases)
    attr_is_cont: np.ndarray   # bool (A,)
    n_bins: np.ndarray         # int32 (A,) live bins per attribute
    bin_edges: tuple[np.ndarray, ...]  # per attr: float64 (n_bins,) upper edge
                               # of each bin == split threshold for `<= bin b`;
                               # for discrete attrs: the category codes.
    n_classes: int
    attr_names: tuple[str, ...] = ()

    @property
    def n_cases(self) -> int:
        return int(self.x.shape[0])

    @property
    def n_attrs(self) -> int:
        return int(self.x.shape[1])

    @property
    def max_bins(self) -> int:
        return int(self.n_bins.max()) if self.n_bins.size else 0

    def threshold_value(self, attr: int, split_bin: int) -> float:
        """Raw-space threshold of the split ``x[attr] <= split_bin``."""
        return float(self.bin_edges[attr][split_bin])

    def subset(self, idx: np.ndarray) -> "BinnedDataset":
        return dataclasses.replace(self, x=self.x[idx], y=self.y[idx],
                                   w=self.w[idx])


def _bin_continuous(col: np.ndarray, max_bins: int) -> tuple[np.ndarray, np.ndarray]:
    if max_bins < 1:
        raise ValueError(f"max_bins must be >= 1, got {max_bins}")
    known = ~np.isnan(col)
    binned = np.full(col.shape, UNKNOWN, dtype=np.int32)
    if not known.any():
        # All-unknown column: no domain, no edges — every case keeps bin -1
        # and the attribute can never split (its histogram is empty).
        return binned, np.zeros((0,), dtype=np.float64)
    vals = col[known].astype(np.float64)
    domain = np.unique(vals)
    if domain.size <= max_bins:
        # Exact rank space: bin == index of the value in the sorted domain.
        # A constant column degenerates to a single bin [value].
        binned[known] = np.searchsorted(domain, vals).astype(np.int32)
        return binned, domain
    # Quantile binning: edges are *actual domain values* so that the split
    # threshold is still "a value of A in the whole training set".
    qs = np.linspace(0.0, 1.0, max_bins + 1)[1:-1]
    cut = np.unique(np.quantile(domain, qs, method="nearest"))
    # Skewed quantiles may collapse onto the domain maximum; keep only cuts
    # strictly below it so the final edge (== domain max) is unique and no
    # trailing bin is structurally empty.  max_bins=1 (qs empty) and a fully
    # collapsed cut both degenerate to one bin covering the whole domain.
    cut = cut[cut < domain[-1]]
    # side="left": a value equal to cut[i] lands in bin i, whose upper edge is
    # cut[i] — so the split "x <= edge[b]" includes its own edge value.
    binned[known] = np.searchsorted(cut, vals, side="left").astype(np.int32)
    edges = np.concatenate([cut, domain[-1:]])
    return binned, edges


def _bin_discrete(col: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    col = col.astype(np.int64)
    known = col >= 0
    binned = np.full(col.shape, UNKNOWN, dtype=np.int32)
    n_values = int(col[known].max()) + 1 if known.any() else 0
    binned[known] = col[known].astype(np.int32)
    return binned, np.arange(n_values, dtype=np.float64)


def fit(
    columns: Sequence[np.ndarray],
    y: np.ndarray,
    *,
    attr_is_cont: Sequence[bool],
    n_classes: int | None = None,
    max_bins: int = 256,
    w: np.ndarray | None = None,
    attr_names: Sequence[str] = (),
) -> BinnedDataset:
    """Build the rank-space dataset from raw columns (YaDT stores by column).

    Discrete columns hold small non-negative integer codes (negative =
    unknown); continuous columns hold floats (NaN = unknown).
    """
    n = len(y)
    cols, edges = [], []
    for col, is_cont in zip(columns, attr_is_cont, strict=True):
        col = np.asarray(col)
        if col.shape != (n,):
            raise ValueError(f"column shape {col.shape} != ({n},)")
        b, e = _bin_continuous(col, max_bins) if is_cont else _bin_discrete(col)
        cols.append(b)
        edges.append(e)
    x = np.stack(cols, axis=1) if cols else np.zeros((n, 0), np.int32)
    y = np.asarray(y, dtype=np.int32)
    if n_classes is None:
        n_classes = int(y.max()) + 1 if n else 0
    w = np.ones(n, np.float32) if w is None else np.asarray(w, np.float32)
    return BinnedDataset(
        x=x, y=y, w=w,
        attr_is_cont=np.asarray(attr_is_cont, dtype=bool),
        n_bins=np.array([max(len(e), 1) for e in edges], dtype=np.int32),
        bin_edges=tuple(edges),
        n_classes=int(n_classes),
        attr_names=tuple(attr_names) or tuple(f"a{i}" for i in range(len(cols))),
    )


def from_binned(
    x: np.ndarray,
    y: np.ndarray,
    *,
    attr_is_cont: Sequence[bool],
    n_bins: Sequence[int],
    n_classes: int,
    w: np.ndarray | None = None,
) -> BinnedDataset:
    """Wrap already-binned integer data (used by tests / generators)."""
    x = np.asarray(x, dtype=np.int32)
    n_bins = np.asarray(n_bins, dtype=np.int32)
    edges = tuple(np.arange(int(b), dtype=np.float64) for b in n_bins)
    w = np.ones(len(y), np.float32) if w is None else np.asarray(w, np.float32)
    return BinnedDataset(
        x=x, y=np.asarray(y, np.int32), w=w,
        attr_is_cont=np.asarray(attr_is_cont, dtype=bool),
        n_bins=n_bins, bin_edges=edges, n_classes=int(n_classes),
        attr_names=tuple(f"a{i}" for i in range(x.shape[1])),
    )
