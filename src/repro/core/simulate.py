"""Discrete-event simulator of the YaDT-FF farm (paper Figs. 8/9/13, Table 2).

This container exposes a single CPU core, so the paper's speedup-vs-workers
curves cannot be measured as wall clock.  Instead we *replay the real task
DAG* — recorded from an actual tree build (``c45.build(task_trace=...)``) —
through a faithful event-level model of the FastFlow farm:

  * one serial emitter (start-up dispatch, per-feedback handling, per-task
    emission overhead — its busy fraction reproduces Fig. 14);
  * ``n_workers`` serial workers with bounded FIFO input queues;
  * the DRR / OD / WS policies of :mod:`repro.core.scheduler`, consulting
    queue occupancy exactly at dispatch time (FastFlow semantics: the
    emitter spins when every queue is full);
  * NP tasks (one ``node::split`` per node) or NAP tasks (``splitPre`` at the
    emitter, one ``splitAtt`` per attribute on workers, ``splitPost`` barrier
    at the emitter) chosen per node by the configured ``buildAttTest`` cost
    model — the schedule of paper Fig. 15.

Task service times follow the paper's grain model (quicksort-dominated:
``c·r·log r``) with the constant κ calibrated against a measured sequential
build, so simulated speedups are anchored to real work.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Sequence

from repro.core import cost_models
from repro.core.scheduler import Policy, QueueState, make_policy


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Service-time model (seconds) for farm entities."""
    kappa: float = 1e-8        # seconds per grain unit (calibrated)
    task_fixed: float = 2e-6   # per-task fixed worker overhead
    emit_overhead: float = 5e-7  # emitter cost per handled/emitted task
    freq_unit: float = 1.0     # computeFrequencies / partition grain per case

    def node_cost(self, r: float, c: float) -> float:
        g = self.freq_unit * r + c * r * max(math.log2(max(r, 2.0)), 1.0)
        return self.task_fixed + self.kappa * g

    def leaf_cost(self, r: float) -> float:
        return self.task_fixed + self.kappa * self.freq_unit * max(r, 1.0)

    def att_cost(self, r: float) -> float:
        g = r * max(math.log2(max(r, 2.0)), 1.0)
        return self.task_fixed + self.kappa * g

    def pre_cost(self, r: float) -> float:
        return self.task_fixed + self.kappa * self.freq_unit * max(r, 1.0)


def calibrate(trace: Sequence[dict], measured_seq_seconds: float,
              **kw) -> CostModel:
    """Fix κ so the modelled sequential time matches a measured build."""
    base = CostModel(kappa=1.0, task_fixed=0.0, emit_overhead=0.0)
    grain = sum(base.node_cost(t["r"], max(t["c"], 1)) if t["n_children"]
                else base.leaf_cost(t["r"]) for t in trace)
    return CostModel(kappa=measured_seq_seconds / max(grain, 1e-12), **kw)


def sequential_time(trace: Sequence[dict], cm: CostModel) -> float:
    return sum(cm.node_cost(t["r"], max(t["c"], 1)) if t["n_children"]
               else cm.leaf_cost(t["r"]) for t in trace)


@dataclasses.dataclass
class SimResult:
    makespan: float
    seq_time: float
    emitter_busy: float
    worker_busy: list[float]
    n_node_tasks: int
    n_att_tasks: int
    nap_choices: list[tuple[int, bool]]   # (depth, used_attribute_par)

    @property
    def speedup(self) -> float:
        return self.seq_time / self.makespan if self.makespan > 0 else 0.0


class _Workers:
    """Per-worker schedule; exposes queue state *as of* a given time."""

    def __init__(self, n: int, cap: int):
        self.free = [0.0] * n
        self.cap = cap
        self.busy = [0.0] * n
        # Queue *occupancy* lasts until the worker pops the task (capacity
        # checks); queued *weight* lasts until completion — FastFlow's
        # ws_scheduler decrements the load only when the result flows back,
        # i.e. running tasks still count (matches core/farm.py accounting).
        self.pending_occ: list[deque] = [deque() for _ in range(n)]
        self.pending_w: list[deque] = [deque() for _ in range(n)]

    def views(self, t: float) -> list[QueueState]:
        out = []
        for i in range(len(self.free)):
            occ, pw = self.pending_occ[i], self.pending_w[i]
            while occ and occ[0] <= t:
                occ.popleft()
            while pw and pw[0][0] <= t:
                pw.popleft()
            out.append(QueueState(tasks=len(occ),
                                  weight=sum(w for _, w in pw),
                                  cap=self.cap))
        return out

    def dispatch(self, i: int, arrival: float, cost: float, weight: float
                 ) -> float:
        start = max(self.free[i], arrival)
        self.free[i] = start + cost
        self.busy[i] += cost
        self.pending_occ[i].append(start)
        self.pending_w[i].append((self.free[i], weight))
        return self.free[i]

    def earliest_pop(self) -> float:
        times = [p[0] for p in self.pending_occ if p]
        return min(times) if times else math.inf


def simulate(
    trace: Sequence[dict],
    *,
    n_workers: int,
    strategy: str = "nap",                 # "np" | "nap"
    policy: str | Policy = "ws",
    queue_size: int = 4096,
    cost: CostModel | None = None,
    cost_model: str = "nsq",               # buildAttTest variant (NAP only)
    alpha: float = 1000.0,
) -> SimResult:
    """Replay a recorded task DAG through the farm model."""
    cm = cost or CostModel()
    pol = policy if isinstance(policy, Policy) else make_policy(policy)
    cap = getattr(pol, "forced_capacity", queue_size)
    workers = _Workers(n_workers, cap)

    by_id = {t["node_id"]: t for t in trace}
    children: dict[int, list[int]] = {t["node_id"]: [] for t in trace}
    for t in trace:
        if t["parent"] >= 0 and t["parent"] in children:
            children[t["parent"]].append(t["node_id"])
    n_total = max((t["r"] for t in trace if t["parent"] < 0), default=1)

    emitter_clock = 0.0
    emitter_busy = 0.0
    events: list[tuple[float, int, str, int]] = []   # (t, seq, kind, node)
    seq = 0
    att_left: dict[int, int] = {}
    n_node_tasks = n_att_tasks = 0
    nap_choices: list[tuple[int, bool]] = []

    def emit(node_id: int, kind: str, svc_cost: float, weight: float) -> None:
        nonlocal emitter_clock, emitter_busy, seq
        emitter_clock += cm.emit_overhead
        emitter_busy += cm.emit_overhead
        while True:
            i = pol.pick(weight, workers.views(emitter_clock))
            if i is not None:
                break
            nxt = workers.earliest_pop()           # spin until a queue frees
            emitter_clock = max(emitter_clock, nxt if nxt < math.inf
                                else emitter_clock)
            if nxt is math.inf:
                raise RuntimeError("deadlock: all queues full, none draining")
        done = workers.dispatch(i, emitter_clock, svc_cost, weight)
        seq += 1
        heapq.heappush(events, (done, seq, kind, node_id))

    def process_node(node_id: int) -> None:
        """Emitter handles a ready node: NP task or NAP decomposition."""
        nonlocal emitter_clock, emitter_busy, n_node_tasks, n_att_tasks
        t = by_id[node_id]
        r, c = t["r"], max(t["c"], 1)
        if t["n_children"] == 0:
            emit(node_id, "NODE", cm.leaf_cost(r), weight=max(r, 1))
            n_node_tasks += 1
            return
        use_att = strategy == "nap" and bool(cost_models.build_att_test(
            cost_model, n_total_cases=float(n_total), r=float(r), c=float(c),
            alpha=alpha))
        nap_choices.append((t["depth"], use_att))
        if use_att:
            # splitPre runs at the emitter before attribute tasks (§7.28-38)
            pre = cm.pre_cost(r)
            emitter_clock += pre
            emitter_busy += pre
            att_left[node_id] = c
            for _ in range(c):
                emit(node_id, "ATT", cm.att_cost(r), weight=max(r, 1))
            n_att_tasks += c
        else:
            emit(node_id, "NODE", cm.node_cost(r, c), weight=max(r, 1))
            n_node_tasks += 1

    process_node(0)                                   # root (§7.3-10)
    while events:
        done_t, _, kind, node_id = heapq.heappop(events)
        emitter_clock = max(emitter_clock, done_t)
        emitter_clock += cm.emit_overhead             # feedback handling
        emitter_busy += cm.emit_overhead
        if kind == "ATT":
            att_left[node_id] -= 1
            if att_left[node_id] > 0:
                continue
            post = cm.pre_cost(1)                     # splitPost at emitter
            emitter_clock += post
            emitter_busy += post
        for ch in children[node_id]:
            process_node(ch)

    makespan = max([emitter_clock] + workers.free)
    return SimResult(
        makespan=makespan,
        seq_time=sequential_time(trace, cm),
        emitter_busy=emitter_busy,
        worker_busy=workers.busy,
        n_node_tasks=n_node_tasks,
        n_att_tasks=n_att_tasks,
        nap_choices=nap_choices,
    )
