"""The paper's ``buildAttTest`` cost models (Sect. 4, second issue).

Given a node with ``r`` training cases and ``c`` active attributes, decide
whether to parallelise over *attributes* (NAP, fine grain — returns True) or
over *nodes* (NP — returns False).  The three variants evaluated in the paper
(Fig. 12; |T| is the whole-training-set size):

  alpha :  α < r                (hand-tuned threshold, α = 1000)
  nlogn :  |T| < c·r·log2(r)    (average-case quicksort grain)
  nsq   :  |T| < c·r²           (worst-case grain; best performing — most
                                 task over-provisioning)

All tests are monotone in ``r``, so once a subtree switches to node
parallelism it never switches back — the property the paper exploits and the
frontier engine's two-phase schedule relies on.

Functions are jnp-traceable (used inside the superstep for Fig. 15-style
statistics) and also callable with plain floats (used by the farm simulator
per task).
"""

from __future__ import annotations

import jax.numpy as jnp

COST_MODELS = ("alpha", "nlogn", "nsq")


def build_att_test(model: str, *, n_total_cases: float, r, c,
                   alpha: float = 1000.0):
    """True where the node should use attribute parallelisation (NAP)."""
    r = jnp.asarray(r, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    if model == "alpha":
        return r > alpha
    if model == "nlogn":
        return n_total_cases < c * r * jnp.log2(jnp.maximum(r, 2.0))
    if model == "nsq":
        return n_total_cases < c * r * r
    raise ValueError(f"unknown cost model {model!r}; choose from {COST_MODELS}")


def task_grain(model: str, *, r: float, c: float) -> float:
    """Analytic node-processing grain used by the simulator's cost table.

    The paper models node::split as quicksort-dominated: average c·r·log r,
    worst-case c·r².  ``task_grain`` returns the average-case estimate (the
    simulator calibrates the constant from measured oracle timings).
    """
    import math
    r = max(float(r), 1.0)
    return float(c) * r * max(math.log2(r), 1.0)
