"""Unrollable scan — exact roofline accounting for loopy programs.

``compiled.cost_analysis()`` counts a while-loop body ONCE, ignoring the
trip count (verified empirically; a 10-iteration scan reports 1 iteration
of flops).  Every scanned model would therefore under-report flops/bytes/
collective-bytes by ~n_layers x n_chunks in the roofline table.

Fix: all model-internal scans go through :func:`scan` below.  Under
``unrolled()`` (used only by the dry-run's *analysis* lowering) it expands
to a Python loop, so the compiled HLO contains every iteration and
cost_analysis is exact.  The production artifact keeps ``lax.scan``
(compact HLO, fast compiles); the dry-run lowers both and takes memory
from the scanned artifact, costs from the unrolled one.

``analysis_chunk`` lets memory-motivated chunk sizes (flash attention, CE)
grow in analysis mode so the unrolled graph stays compilable — for those
loops the chunk size does not change total flops, only peak memory, which
is measured on the scanned artifact anyway.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

_STATE = {"unroll": False}


@contextlib.contextmanager
def unrolled():
    old = _STATE["unroll"]
    _STATE["unroll"] = True
    try:
        yield
    finally:
        _STATE["unroll"] = old


def is_unrolled() -> bool:
    return _STATE["unroll"]


def analysis_chunk(prod_chunk: int, total: int, max_blocks: int = 8) -> int:
    """Chunk size to use: production value, or total/max_blocks when
    unrolled (keeps the unrolled block count bounded)."""
    if not _STATE["unroll"]:
        return prod_chunk
    return max(prod_chunk, -(-total // max_blocks))


def scan(f: Callable, init: Any, xs: Any, length: int | None = None):
    """Drop-in for ``jax.lax.scan`` (no reverse/unroll kwargs needed here)."""
    if not _STATE["unroll"]:
        return jax.lax.scan(f, init, xs, length=length)
    if xs is None:
        n = length
        slices = [None] * n
    else:
        n = length or jax.tree.leaves(xs)[0].shape[0]
        slices = [jax.tree.map(lambda a, i=i: a[i], xs) for i in range(n)]
    carry = init
    ys = []
    for xi in slices:
        carry, y = f(carry, xi)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *ys)
    return carry, stacked
