"""Shared utilities."""
