"""Partitioning rules: DP / TP / EP / SP over the production mesh.

Baseline layout (the paper-faithful starting point for §Perf; hillclimbed
variants live behind ``layout=``):

  * **data axis (+ pod axis when multi-pod)** — batch dimension of every
    activation (pure DP across pods, DP within a pod).
  * **model axis** — tensor parallelism where divisibility is universal
    across the fleet: d_ff (Megatron MLP), vocab (parallel unembed + CE),
    experts (EP: 16 experts over 16-way model axis), and the fused
    ``heads*head_dim`` projection columns.
  * **ZeRO-3 storage** — every >=2-D parameter additionally shards its first
    dimension over the data axis; XLA materialises the all-gather before use
    and the reduce-scatter on the gradient (both visible in the collective
    roofline term).
  * **SP for serving** — decode-shape KV caches shard the *sequence* axis
    over the model axis (and over data too at batch 1); the plain-reduction
    attention in ``layers.decode_attention`` then compiles to a distributed
    flash-decode (partial max/sum + psum).

Only parameters and step inputs/outputs are constrained; intermediate
shardings are left to the SPMD partitioner (constraint points documented in
DESIGN.md §8 are added where propagation is known to go wrong).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec


# --------------------------------------------------------------------------
# mesh helpers
# --------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch dimension (pod DP + in-pod DP)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(mesh: Mesh, dim: int, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _is_stacked(path) -> bool:
    """True for scan-over-cycles parameters: leading dim = n_cycles."""
    return any(isinstance(e, jax.tree_util.DictKey) and str(e.key) == "scan"
               for e in path)


def param_pspec(path, leaf, mesh: Mesh, *, zero3: bool = True) -> P:
    """PartitionSpec for one parameter leaf (see module docstring)."""
    if _is_stacked(path):
        # dim0 is the layer-stack axis (scan slices it): replicate it and
        # apply the per-layer rules to the remaining dims.
        inner = param_pspec(
            [e for e in path
             if not (isinstance(e, jax.tree_util.DictKey)
                     and str(e.key) == "scan")],
            jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype), mesh,
            zero3=zero3)
        return P(None, *inner)
    name = _leaf_name(path)
    shape = leaf.shape
    nd = len(shape)
    dp = "data" if (zero3 and "data" in mesh.axis_names) else None

    if nd <= 1:
        return P()
    if name == "embed":                       # (V, D)
        return P("model" if _fits(mesh, shape[0], "model") else None,
                 dp if _fits(mesh, shape[1], dp) else None)
    if name == "lm_head":                     # (D, V)
        return P(dp if _fits(mesh, shape[0], dp) else None,
                 "model" if _fits(mesh, shape[1], "model") else None)
    if name == "router":
        return P(None, None)
    if nd == 3:                               # expert weights (E, ·, ·)
        e_ok = _fits(mesh, shape[0], "model")
        d_ok = _fits(mesh, shape[1], dp)
        return P("model" if e_ok else None, dp if d_ok else None, None)
    # generic 2-D: ZeRO-3 on dim0, TP on dim1
    d0 = dp if _fits(mesh, shape[0], dp) else None
    d1 = "model" if _fits(mesh, shape[1], "model") else None
    return P(d0, d1)


def param_shardings(param_tree: Any, mesh: Mesh, *, zero3: bool = True):
    """Map a (shape-)pytree of params to NamedShardings."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_pspec(path, leaf, mesh, zero3=zero3)),
        param_tree)


# --------------------------------------------------------------------------
# step input / output rules
# --------------------------------------------------------------------------


def batch_shardings(mesh: Mesh, batch_tree: Any):
    """Batch dict (tokens/labels/frontend_embeds): batch dim over DP axes."""
    dp = batch_axes(mesh)

    def rule(path, leaf):
        b = leaf.shape[0]
        first = dp if b % axis_size(mesh, dp) == 0 else (
            "data" if b % axis_size(mesh, "data") == 0 else None)
        return NamedSharding(mesh, P(first, *([None] * (len(leaf.shape) - 1))))

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_pspec(cfg: ModelConfig, mesh: Mesh, layer: int,
                field: str, shape: tuple[int, ...], *, long: bool) -> P:
    """Serving-cache sharding: SP on global-KV sequence, DP on batch."""
    kind = cfg.block_kind(layer)
    dp = batch_axes(mesh)
    b = shape[0]
    b_axes = dp if b % axis_size(mesh, dp) == 0 else (
        "data" if b % axis_size(mesh, "data") == 0 else None)

    if kind == "global" and field in ("k", "v"):
        seq_axes: Any = "model"
        if b_axes is None:                    # batch 1: give seq both axes
            seq_axes = tuple(a for a in ("pod", "data", "model")
                             if a in mesh.axis_names)
        if shape[1] % axis_size(mesh, seq_axes) == 0:
            return P(b_axes, seq_axes, None, None)
        return P(b_axes, None, None, None)
    if kind == "local" and field in ("k", "v"):
        return P(b_axes, None, None, None)
    if kind == "rwkv" and field == "state":
        h_ok = shape[1] % axis_size(mesh, "model") == 0
        return P(b_axes, "model" if h_ok else None, None, None)
    if kind == "rglru":
        if field == "h":
            w_ok = shape[1] % axis_size(mesh, "model") == 0
            return P(b_axes, "model" if w_ok else None)
        if field == "conv":
            w_ok = shape[2] % axis_size(mesh, "model") == 0
            return P(b_axes, None, "model" if w_ok else None)
    # token-shift carries etc.
    return P(b_axes, *([None] * (len(shape) - 1)))


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree: list,
                    *, long: bool = False):
    out = []
    for i, slot in enumerate(cache_tree):
        out.append({
            f: NamedSharding(mesh, cache_pspec(cfg, mesh, i, f, v.shape,
                                               long=long))
            for f, v in slot.items()})
    return out


def logits_sharding(cfg: ModelConfig, mesh: Mesh, batch: int):
    dp = batch_axes(mesh)
    b_axes = dp if batch % axis_size(mesh, dp) == 0 else (
        "data" if batch % axis_size(mesh, "data") == 0 else None)
    v_ok = cfg.vocab_size % axis_size(mesh, "model") == 0
    return NamedSharding(mesh, P(b_axes, "model" if v_ok else None))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
