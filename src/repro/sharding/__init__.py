"""Sharding rules: logical-axis partitioning for params/batches/caches."""
