"""Activation sharding constraints (propagation anchors).

XLA's sharding propagation loses the batch axis through scan+remat+gather
chains (empirically: the phi4 train cell replicated (B, S, d_ff) activations
and all-gathered 34 GB per layer).  Production frameworks pin activations
explicitly; these helpers are the pin points used inside the model code.

The active mesh geometry is process-global, set by the launch layer
(``specs.lower_cell``) via :func:`activation_sharding`, so model code stays
mesh-agnostic; with no context active every helper is a no-op (pure-CPU
unit tests).  Axes are applied only when the dimension is divisible — e.g.
batch 1 at ``long_500k`` simply stays replicated.
"""

from __future__ import annotations

import contextlib
from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"dp": ("data",), "tp": "model", "dp_size": 1, "tp_size": 1,
          "enabled": False,
          # --- layout knobs (hillclimbed; see EXPERIMENTS.md §Perf) -------
          "moe2d": False,    # shard MoE capacity axis over DP
          "yadt_rs": True,   # reduce-scatter the frontier histogram over K (confirmed win)
          "yadt_compact": True,  # keep compacted live-case buffers DP-sharded
          "kv_seq_shard": False,  # capture prefill KV seq-sharded over TP
          }


@contextlib.contextmanager
def activation_sharding(dp: Sequence[str], dp_size: int,
                        tp: str = "model", tp_size: int = 1, **knobs):
    old = dict(_STATE)
    _STATE.update(dp=tuple(dp), tp=tp, dp_size=int(dp_size),
                  tp_size=int(tp_size), enabled=True, **knobs)
    try:
        yield
    finally:
        _STATE.clear()
        _STATE.update(old)


def from_mesh(mesh, **knobs):
    from repro.sharding import partitioning as part
    dp = part.batch_axes(mesh)
    return activation_sharding(
        dp, part.axis_size(mesh, dp), "model",
        mesh.shape.get("model", 1), **knobs)


def _constrain(x, spec: P):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x     # no mesh in scope


def _dp_for(dim: int):
    return _STATE["dp"] if dim % max(_STATE["dp_size"], 1) == 0 else None


def _tp_for(dim: int):
    return _STATE["tp"] if dim % max(_STATE["tp_size"], 1) == 0 else None


def shard_batch(x):
    """Pin dim0 = batch to the DP axes; other dims replicated."""
    if not _STATE["enabled"]:
        return x
    return _constrain(x, P(_dp_for(x.shape[0]),
                           *([None] * (x.ndim - 1))))


def shard_batch_tp_last(x):
    """Pin (batch, ..., feature): batch to DP, last dim to TP."""
    if not _STATE["enabled"]:
        return x
    return _constrain(x, P(_dp_for(x.shape[0]),
                           *([None] * (x.ndim - 2)),
                           _tp_for(x.shape[-1])))


def shard_frontier_hist(x):
    """(K, A, B+1, C) frontier histogram.

    Baseline: replicated (segment-sum partials all-reduced everywhere —
    the NAP splitPost barrier as one fat collective).  With ``yadt_rs``
    (hillclimbed): slot axis K sharded over TP — the partials are
    reduce-scattered (half the volume of an all-reduce) and the gain scan
    + argmax run K-sharded; only the per-slot decisions (a few ints per
    node) are gathered for case routing.
    """
    if not (_STATE["enabled"] and _STATE["yadt_rs"]):
        return x
    return _constrain(x, P(_tp_for(x.shape[0]),
                           *([None] * (x.ndim - 1))))


def shard_active_cases(x):
    """Compacted live-case buffers ``(N_active,)`` / ``(N_active, A)``.

    The gather that builds them reads DP-sharded case columns; without a
    pin the partitioner tends to all-gather the result (the gathered index
    vector is replicated).  Keeping dim0 on the DP axes makes the bucketed
    histogram input land exactly where the full-N input lived — zero
    resharding on either side of the compaction switch.
    """
    if not (_STATE["enabled"] and _STATE["yadt_compact"]):
        return x
    return _constrain(x, P(_dp_for(x.shape[0]),
                           *([None] * (x.ndim - 1))))


def shard_kv_capture(x):
    """Prefill-captured KV (B, S, KV, hd): seq over TP under kv_seq_shard
    (matches the serving cache layout => no reshard, 1/tp the footprint)."""
    if not (_STATE["enabled"] and _STATE["kv_seq_shard"]):
        return x
    return _constrain(x, P(_dp_for(x.shape[0]), _tp_for(x.shape[1]),
                           None, None))


def shard_experts(x):
    """Pin (E, C, ...) expert-major tensors.

    Baseline (paper-faithful EP): E over TP only — each expert's capacity
    batch is computed whole on its model shard, so per-device expert flops
    divide by tp only (measured 16x useful-flops loss on the MoE cells).
    With the ``moe2d`` knob (hillclimbed default): capacity additionally
    shards over DP — per-device flops divide by the full mesh.
    """
    if not _STATE["enabled"]:
        return x
    dims = [_tp_for(x.shape[0])] + [None] * (x.ndim - 1)
    if _STATE["moe2d"] and x.ndim >= 2:
        dims[1] = _dp_for(x.shape[1])
    return _constrain(x, P(*dims))
