"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* first jax
init and only then calls this.
"""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across jax versions.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg) only exist on
    newer jax; older versions treat every axis as Auto already, which is
    exactly what we ask for — so fall back to the plain call.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """v5e pod grid: (data=16, model=16) per pod; 'pod' axis across pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4) -> jax.sharding.Mesh:
    """Small mesh over host devices (tests; needs device_count >= data*model)."""
    return make_mesh_compat((data, model), ("data", "model"))
