import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh and record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_9b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out reports/dryrun.json

Success of ``.lower().compile()`` for a cell proves the sharding config is
coherent (no mismatched collectives, fits compile-time memory accounting);
failures here are bugs in the framework, not in the run.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import base as cfgbase
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, lower_cell, make_cell


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, analyze: bool = True) -> dict:
    """Lower + compile one cell.

    Two artifacts (see utils/scan.py for why):
      * production (scanned) — the compile proof + memory_analysis;
      * analysis (unrolled)  — exact flops/bytes/collective accounting,
        skipped on the multi-pod pass (roofline table is single-pod).
    """
    from repro.launch.specs import make_analysis_cells

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.time()
    cell = make_cell(arch, shape_name, mesh)
    compiled = lower_cell(cell, mesh).compile()
    t_prod = time.time() - t0
    mem = compiled.memory_analysis()

    out = dict(status="ok", t_prod_s=round(t_prod, 1),
               mem_args_gb=mem.argument_size_in_bytes / 1e9,
               mem_temp_gb=mem.temp_size_in_bytes / 1e9,
               mem_out_gb=mem.output_size_in_bytes / 1e9)
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_desc}] compile OK {t_prod:.0f}s"
              f" | memory/device: args {out['mem_args_gb']:.2f} GB"
              f" temp {out['mem_temp_gb']:.2f} GB")

    if analyze:
        t0 = time.time()
        flops = bytes_ = coll = 0.0
        coll_by_op: dict[str, float] = {}
        for acell, scale in make_analysis_cells(arch, shape_name, mesh):
            acomp = lower_cell(acell, mesh, unroll=True).compile()
            r = rl.analyze(acomp, arch=arch, shape=shape_name,
                           mesh_desc=mesh_desc, n_devices=mesh.size)
            flops += scale * r.device_flops
            bytes_ += scale * r.device_bytes
            coll += scale * r.device_coll_bytes
            for k, v in r.coll_by_op.items():
                coll_by_op[k] = coll_by_op.get(k, 0.0) + scale * v
        report = rl.Roofline(
            arch=arch, shape=shape_name, mesh=mesh_desc,
            device_flops=flops, device_bytes=bytes_, device_coll_bytes=coll,
            coll_by_op=coll_by_op,
            peak_mem_bytes=mem.temp_size_in_bytes
            + mem.argument_size_in_bytes,
            arg_bytes=mem.argument_size_in_bytes,
            model_flops=rl.model_flops_for(arch, shape_name))
        out.update(t_analysis_s=round(time.time() - t0, 1),
                   **report.as_dict(mesh.size))
        if verbose:
            print(f"  costs/device: {flops:.3e} flops, {bytes_:.3e} B, "
                  f"{coll:.3e} coll B  (unrolled, {out['t_analysis_s']:.0f}s)")
            print(f"  roofline: compute {report.t_compute*1e3:.2f} ms | "
                  f"memory {report.t_memory*1e3:.2f} ms | collective "
                  f"{report.t_collective*1e3:.2f} ms -> {report.bottleneck}"
                  f" | useful-flops {report.useful_flops_ratio(mesh.size):.2f}")
    return out


def cells_to_run() -> list[tuple[str, str]]:
    cells = []
    for arch in cfgbase.ARCH_IDS:
        if arch == "yadt":
            cells.append((arch, "train_4k"))
            continue
        cfg = cfgbase.get_config(arch)
        for shape in cfgbase.runnable_shapes(cfg):
            cells.append((arch, shape.name))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-analysis", action="store_true",
                    help="compile proof + memory only (multi-pod default)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    assert jax.device_count() == 512, "dry-run needs 512 host devices"

    analyze = not (args.no_analysis or args.multi_pod)
    todo = cells_to_run() if args.all else [(args.arch, args.shape)]
    results = {}
    for arch, shape in todo:
        key = f"{arch}/{shape}"
        try:
            results[key] = run_cell(arch, shape, multi_pod=args.multi_pod,
                                    analyze=analyze)
        except Exception as e:                        # record, keep going
            traceback.print_exc()
            results[key] = dict(status="fail", error=f"{type(e).__name__}: {e}")
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    print(f"\n== {n_ok}/{len(results)} cells OK "
          f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})")
    if n_ok != len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
