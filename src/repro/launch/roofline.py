"""Roofline term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute    = device_flops   / peak_flops
  memory     = device_bytes   / hbm_bw
  collective = device_coll_bytes / ici_bw

``compiled.cost_analysis()`` reports **per-device** flops / bytes on
partitioned modules (verified empirically), so the terms above divide by
per-chip peaks directly — algebraically identical to the brief's
``global / (chips x peak)`` form.

Collective bytes are not in cost_analysis: we parse the post-partitioning
HLO (``compiled.as_text()``) and sum the per-device volume of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
scaled by the ring factor of its replica-group size g:

  all-gather       result x (g-1)/g      (result = gathered local tensor)
  all-reduce       2 x result x (g-1)/g  (reduce-scatter + all-gather)
  reduce-scatter   result x (g-1)       (input = g x result shards)
  all-to-all       result x (g-1)/g
  collective-permute  result
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*(?P<dtype>\w+)\[(?P<dims>[\d,]*)\][^=]*?"
    r"\b(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, *, n_devices: int
                     ) -> tuple[float, dict[str, float]]:
    """Per-device communicated bytes (see module docstring for the model)."""
    total = 0.0
    by_op: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[-1][:40]:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("dtype"), m.group("dims"))
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        ring = (g - 1) / g
        vol = {"all-gather": size * ring,
               "all-reduce": 2 * size * ring,
               "reduce-scatter": size * (g - 1),
               "all-to-all": size * ring,
               "collective-permute": float(size)}[op]
        total += vol
        by_op[op] = by_op.get(op, 0.0) + vol
    return total, by_op


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    device_flops: float
    device_bytes: float
    device_coll_bytes: float
    coll_by_op: dict[str, float]
    peak_mem_bytes: float
    arg_bytes: float
    model_flops: float        # 6*N*D (dense) / 6*N_active*D (MoE), global

    @property
    def t_compute(self) -> float:
        return self.device_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.device_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.device_coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_seconds(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def useful_flops_ratio(self, n_devices: int) -> float:
        hlo_global = self.device_flops * n_devices
        return self.model_flops / hlo_global if hlo_global else 0.0

    def as_dict(self, n_devices: int) -> dict[str, Any]:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh,
            device_flops=self.device_flops, device_bytes=self.device_bytes,
            device_coll_bytes=self.device_coll_bytes,
            coll_by_op=self.coll_by_op,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            peak_mem_gb=self.peak_mem_bytes / 1e9,
            arg_gb=self.arg_bytes / 1e9,
            model_flops=self.model_flops,
            useful_flops_ratio=self.useful_flops_ratio(n_devices),
        )


def model_flops_for(arch: str, shape_name: str) -> float:
    """6*N*D with N = (active) params, D = tokens processed by the step."""
    from repro.configs import base as cfgbase
    if arch == "yadt":
        return 0.0
    cfg = cfgbase.get_config(arch)
    shape = cfgbase.SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch     # decode: one token per row


def analyze(compiled, *, arch: str, shape: str, mesh_desc: str,
            n_devices: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax: one dict per program
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll, by_op = collective_bytes(compiled.as_text(), n_devices=n_devices)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_desc,
        device_flops=float(cost.get("flops", 0.0)),
        device_bytes=float(cost.get("bytes accessed", 0.0)),
        device_coll_bytes=coll, coll_by_op=by_op,
        peak_mem_bytes=float(mem.temp_size_in_bytes
                             + mem.argument_size_in_bytes),
        arg_bytes=float(mem.argument_size_in_bytes),
        model_flops=model_flops_for(arch, shape),
    )
