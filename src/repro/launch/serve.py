"""Serving driver: replicas + WS-scheduled engine over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_9b --reduced \
      --requests 16 --slots 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cfgbase
from repro.models.model import build_model
from repro.serve.engine import Replica, Request, ServingEngine


def serve(arch: str = "gemma2_9b", *, reduced: bool = True,
          n_requests: int = 16, n_replicas: int = 1, n_slots: int = 4,
          max_seq: int = 160, max_new: int = 8, policy: str = "ws",
          seed: int = 0) -> dict:
    cfg = cfgbase.get_config(arch)
    if reduced:
        cfg = cfgbase.reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    replicas = [Replica(model, params, n_slots=n_slots, max_seq=max_seq,
                        seed=seed + i) for i in range(n_replicas)]
    engine = ServingEngine(replicas, policy=policy)

    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    for i in range(n_requests):
        plen = int(rng.integers(4, max_seq - max_new - 2))
        engine.submit(Request(
            uid=i, prompt=rng.integers(1, cfg.vocab_size, plen
                                       ).astype(np.int32),
            max_new_tokens=max_new))
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0
    n_tokens = sum(len(c.tokens) for c in done)
    return dict(completed=len(done), tokens=n_tokens, seconds=dt,
                tok_per_s=n_tokens / dt)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_9b",
                    choices=list(cfgbase.ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", default="ws", choices=("ws", "drr", "od"))
    args = ap.parse_args()
    out = serve(args.arch, reduced=args.reduced, n_requests=args.requests,
                n_replicas=args.replicas, n_slots=args.slots,
                policy=args.policy)
    print(f"{out['completed']} requests, {out['tokens']} tokens in "
          f"{out['seconds']:.1f}s ({out['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
