"""End-to-end training driver.

Wires every substrate together: config registry, sharded data pipeline,
jitted train step, checkpoint/restart, heartbeat + straggler monitors.

  PYTHONPATH=src python -m repro.launch.train --arch phi4_mini --reduced \
      --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On this CPU container use ``--reduced`` (tiny same-family config); on a pod
the same driver runs the full config over the production mesh (pass
``--mesh data,model`` sizes that match the slice).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.data.loader import LoaderConfig, ShardedLoader
from repro.models.frontends import fake_frontend_embeds
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.elastic import HeartbeatMonitor, StragglerMonitor
from repro.train.train_step import TrainState, init_state, make_train_step


def train(arch: str = "phi4_mini", *, reduced: bool = True, steps: int = 20,
          global_batch: int = 8, seq_len: int = 128, lr: float = 3e-4,
          ckpt_dir: str | None = None, ckpt_every: int = 50,
          grad_accum: int = 1, seed: int = 0, log_every: int = 10,
          host_index: int = 0, num_hosts: int = 1,
          config: cfgbase.ModelConfig | None = None) -> dict:
    cfg = config or cfgbase.get_config(arch)
    if reduced and config is None:
        cfg = cfgbase.reduced(cfg)
    model = build_model(cfg)

    loader = ShardedLoader(
        LoaderConfig(global_batch=global_batch, seq_len=seq_len,
                     vocab_size=cfg.vocab_size, seed=seed),
        host_index=host_index, num_hosts=num_hosts)

    opt_cfg = opt.AdamWConfig(lr=lr, warmup_steps=max(2, steps // 10),
                              total_steps=steps)
    step_fn = jax.jit(make_train_step(
        lambda p, b: model.loss_fn(p, b), opt_cfg, grad_accum=grad_accum))

    # --- restore-or-init (fault tolerance: always resumable) --------------
    params = model.init(jax.random.key(seed))
    state = init_state(params)
    start_step = 0
    if ckpt_dir:
        latest = ckpt.latest_valid(ckpt_dir)
        if latest:
            state = ckpt.restore(latest, state)
            start_step = ckpt.manifest_step(latest)
            loader.seek(start_step * max(1, grad_accum))
            print(f"resumed from {latest} at step {start_step}")

    hb = HeartbeatMonitor(timeout=120.0)
    straggle = StragglerMonitor()
    pending_save = None
    fe = fake_frontend_embeds(cfg, global_batch // num_hosts)
    history = []
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
        if fe is not None:
            batch["frontend_embeds"] = fe
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])        # blocks; step wall time is real
        dt = time.perf_counter() - t0
        hb.beat(f"host{host_index}", step)
        straggle.record(f"host{host_index}", dt)
        history.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            if pending_save is not None:
                pending_save.wait()          # surfaces async writer errors
            pending_save = ckpt.save(ckpt_dir, step + 1, state,
                                     blocking=False)
        if not np.isfinite(loss):
            raise RuntimeError(f"loss diverged at step {step}")
    if pending_save is not None:
        pending_save.wait()
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, state, blocking=True)
    return dict(first_loss=history[0], last_loss=history[-1],
                state=state, history=history)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4_mini",
                    choices=list(cfgbase.ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    out = train(args.arch, reduced=args.reduced, steps=args.steps,
                global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                grad_accum=args.grad_accum)
    print(f"loss {out['first_loss']:.4f} -> {out['last_loss']:.4f}")


if __name__ == "__main__":
    main()
