"""Inject the dry-run summary + roofline table into EXPERIMENTS.md markers.

  PYTHONPATH=src python -m repro.launch.update_experiments \
      reports/dryrun_single_pod.json [EXPERIMENTS.md]
"""

from __future__ import annotations

import re
import sys

from repro.launch import report


def inject(md_path: str, marker: str, content: str) -> None:
    with open(md_path) as f:
        text = f.read()
    tag = f"<!-- {marker} -->"
    block = f"{tag}\n{content}\n<!-- /{marker} -->"
    if f"<!-- /{marker} -->" in text:
        text = re.sub(
            rf"<!-- {marker} -->.*?<!-- /{marker} -->", block, text,
            flags=re.S)
    else:
        text = text.replace(tag, block)
    with open(md_path, "w") as f:
        f.write(text)


def main() -> None:
    json_path = sys.argv[1]
    md_path = sys.argv[2] if len(sys.argv) > 2 else "EXPERIMENTS.md"
    inject(md_path, "DRYRUN-SUMMARY", report.summarize(json_path))
    inject(md_path, "ROOFLINE-TABLE", report.render(json_path))
    print(f"updated {md_path} from {json_path}")


if __name__ == "__main__":
    main()
