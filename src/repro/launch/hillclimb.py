import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede every other import (jax locks the device count).

"""Perf hillclimbing driver: lower one cell under layout-knob variants and
diff the roofline terms (the §Perf measure step).

  PYTHONPATH=src python -m repro.launch.hillclimb --arch llama4_scout \
      --shape train_4k --knob moe2d
"""

import argparse
import json
import time

from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import lower_cell, make_analysis_cells, make_cell


def measure(arch: str, shape: str, **knobs) -> dict:
    mesh = make_production_mesh()
    t0 = time.time()
    prod = lower_cell(make_cell(arch, shape, mesh), mesh, **knobs).compile()
    mem = prod.memory_analysis()
    flops = bytes_ = coll = 0.0
    by_op: dict[str, float] = {}
    for acell, scale in make_analysis_cells(arch, shape, mesh):
        comp = lower_cell(acell, mesh, unroll=True, **knobs).compile()
        r = rl.analyze(comp, arch=arch, shape=shape, mesh_desc="16x16",
                       n_devices=mesh.size)
        flops += scale * r.device_flops
        bytes_ += scale * r.device_bytes
        coll += scale * r.device_coll_bytes
        for k, v in r.coll_by_op.items():
            by_op[k] = by_op.get(k, 0.0) + scale * v
    return dict(
        knobs=knobs,
        temp_gb=mem.temp_size_in_bytes / 1e9,
        flops=flops, bytes=bytes_, coll=coll, coll_by_op=by_op,
        t_compute_ms=flops / rl.PEAK_FLOPS * 1e3,
        t_memory_ms=bytes_ / rl.HBM_BW * 1e3,
        t_collective_ms=coll / rl.ICI_BW * 1e3,
        model_flops=rl.model_flops_for(arch, shape),
        useful=rl.model_flops_for(arch, shape) / (flops * mesh.size)
        if flops else 0.0,
        wall_s=round(time.time() - t0, 1),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--knob", action="append", default=[],
                    help="knob=value (value parsed as json; bare name=true)")
    args = ap.parse_args()
    knobs = {}
    for k in args.knob:
        if "=" in k:
            name, val = k.split("=", 1)
            knobs[name] = json.loads(val)
        else:
            knobs[k] = True
    out = measure(args.arch, args.shape, **knobs)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
