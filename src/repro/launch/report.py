"""Render the dry-run JSON into the EXPERIMENTS.md roofline table.

  PYTHONPATH=src python -m repro.launch.report reports/dryrun_single_pod.json
"""

from __future__ import annotations

import json
import sys


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.1f}"


def render(path: str) -> str:
    with open(path) as f:
        results = json.load(f)
    lines = [
        "| arch | shape | compute ms | memory ms | coll ms | bottleneck |"
        " useful-flops | mem/dev GB |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for key, r in results.items():
        if r.get("status") != "ok":
            lines.append(f"| {key.split('/')[0]} | {key.split('/')[1]} |"
                         f" FAIL | | | {r.get('error', '')[:60]} | | |")
            continue
        if "t_compute" not in r:
            lines.append(
                f"| {r.get('arch', key.split('/')[0])} |"
                f" {r.get('shape', key.split('/')[1])} | compile-only |"
                f" | | | | {r['mem_temp_gb']:.1f} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r['t_compute'])} |"
            f" {fmt_ms(r['t_memory'])} | {fmt_ms(r['t_collective'])} |"
            f" {r['bottleneck']} | {r['useful_flops_ratio']:.2f} |"
            f" {r['mem_temp_gb']:.1f} |")
    return "\n".join(lines)


def summarize(path: str) -> str:
    with open(path) as f:
        results = json.load(f)
    ok = [k for k, r in results.items() if r.get("status") == "ok"]
    fail = [k for k, r in results.items() if r.get("status") != "ok"]
    out = [f"{len(ok)}/{len(results)} cells OK"]
    if fail:
        out.append("failed: " + ", ".join(fail))
    return "\n".join(out)


if __name__ == "__main__":
    p = sys.argv[1]
    print(summarize(p))
    print()
    print(render(p))
