"""Step builders + ShapeDtypeStruct input specs for every (arch x shape) cell.

This is the single source the dry-run, the roofline analysis and the tests
lower from.  Nothing here allocates device memory: parameters/caches are
``jax.eval_shape`` trees, inputs are ShapeDtypeStructs, and shardings come
from :mod:`repro.sharding.partitioning`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.frontends import frontend_embeds_spec
from repro.models.model import build_model
from repro.sharding import partitioning as part
from repro.train import optimizer as opt
from repro.train.train_step import TrainState, make_train_step


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    arch: str
    shape: ShapeSpec
    step_fn: Callable
    args: tuple            # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    static_kwargs: dict


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _state_specs(model, cfg: ModelConfig):
    params = jax.eval_shape(model.init, jax.random.key(0))
    m, v = jax.eval_shape(opt.init_moments, params)
    return TrainState(params=params, m=m, v=v,
                      step=_sds((), jnp.int32))


def _state_shardings(state: TrainState, mesh: Mesh) -> TrainState:
    ps = part.param_shardings(state.params, mesh)
    return TrainState(
        params=ps,
        m=part.param_shardings(state.m, mesh),
        v=part.param_shardings(state.v, mesh),
        step=part.replicated(mesh))


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32),
             "labels": _sds((b, s), jnp.int32)}
    fe = frontend_embeds_spec(cfg, b)
    if fe is not None:
        batch["frontend_embeds"] = fe
    return batch


def input_specs(arch: str, shape_name: str) -> dict:
    """Public helper: ShapeDtypeStruct stand-ins for every model input."""
    cfg = cfgbase.get_config(arch)
    shape = cfgbase.SHAPES[shape_name]
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        out = {"tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32)}
        fe = frontend_embeds_spec(cfg, shape.global_batch)
        if fe is not None:
            out["frontend_embeds"] = fe
        return out
    # decode: one new token against a seq_len cache
    model = build_model(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    return {"token": _sds((shape.global_batch, 1), jnp.int32),
            "pos": _sds((shape.global_batch,), jnp.int32),
            "cache": cache}


# --------------------------------------------------------------------------
# cell builders per step kind
# --------------------------------------------------------------------------


def make_cell(arch: str, shape_name: str, mesh: Mesh) -> Cell:
    if arch == "yadt":
        return _yadt_cell(shape_name, mesh)
    cfg = cfgbase.get_config(arch)
    shape = cfgbase.SHAPES[shape_name]
    model = build_model(cfg)

    if shape.kind == "train":
        state = _state_specs(model, cfg)
        state_sh = _state_shardings(state, mesh)
        batch = train_batch_specs(cfg, shape)
        batch_sh = part.batch_shardings(mesh, batch)
        # Microbatching: 4 accumulation steps => per-device microbatch 4,
        # which bounds the remat carry stack + flash working set to ~1/4
        # (the production memory/batch trade at this scale).
        grad_accum = 4 if shape.global_batch >= 64 else 1
        step = make_train_step(
            lambda p, b: model.loss_fn(p, b), opt.AdamWConfig(),
            grad_accum=grad_accum)
        metrics_sh = {k: part.replicated(mesh) for k in
                      ("loss", "n_tokens", "grad_norm", "lr")}
        if cfg.is_moe:
            metrics_sh.update(moe_aux=part.replicated(mesh),
                              moe_dropped=part.replicated(mesh))
        return Cell(arch, shape, step, (state, batch),
                    (state_sh, batch_sh), (state_sh, metrics_sh), {})

    params = jax.eval_shape(model.init, jax.random.key(0))
    params_sh = part.param_shardings(params, mesh)

    if shape.kind == "prefill":
        tokens = _sds((shape.global_batch, shape.seq_len), jnp.int32)
        fe = frontend_embeds_spec(cfg, shape.global_batch)
        args = [params, tokens] + ([fe] if fe is not None else [])
        cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cache_sh = part.cache_shardings(cfg, mesh, cache_shape)
        in_sh = [params_sh,
                 list(part.batch_shardings(mesh, {"t": tokens}).values())[0]]
        if fe is not None:
            in_sh.append(
                list(part.batch_shardings(mesh, {"f": fe}).values())[0])
        out_sh = (part.logits_sharding(cfg, mesh, shape.global_batch),
                  cache_sh)

        def prefill_step(p, t, *rest):
            return model.prefill(p, t, *(rest or (None,)),
                                 max_seq=shape.seq_len)

        return Cell(arch, shape, prefill_step, tuple(args), tuple(in_sh),
                    out_sh, {})

    # decode
    long = shape.name == "long_500k"
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cache_sh = part.cache_shardings(cfg, mesh, cache, long=long)
    token = _sds((shape.global_batch, 1), jnp.int32)
    pos = _sds((shape.global_batch,), jnp.int32)
    tok_sh = list(part.batch_shardings(mesh, {"t": token}).values())[0]
    pos_sh = list(part.batch_shardings(mesh, {"p": pos}).values())[0]
    out_sh = (part.logits_sharding(cfg, mesh, shape.global_batch), cache_sh)

    def decode(p, c, t, pv):
        return model.decode_step(p, c, t, pv)

    return Cell(arch, shape, decode, (params, cache, token, pos),
                (params_sh, cache_sh, tok_sh, pos_sh), out_sh, {})


# --------------------------------------------------------------------------
# the paper's own workload (arch == "yadt"): one frontier superstep
# --------------------------------------------------------------------------


def _yadt_cell(shape_name: str, mesh: Mesh) -> Cell:
    from repro.configs.yadt import WORKLOAD
    from repro.core import frontier
    from repro.core.config import GrowConfig

    wl = WORKLOAD
    # shape cells scale the case count: train_4k = full 10M-case superstep;
    # others reuse the seq_len as a case-count proxy (documented).
    shape = cfgbase.SHAPES[shape_name]
    n_cases = {"train_4k": wl.n_cases,
               "prefill_32k": wl.n_cases // 4,
               "decode_32k": wl.n_cases // 8,
               "long_500k": wl.n_cases // 16}[shape_name]
    n_cases = -(-n_cases // 512) * 512     # shardable on either mesh
    prob = frontier.FrontierProblem(
        n_cases=n_cases, n_attrs=wl.n_attrs, n_bins_max=wl.n_bins,
        n_classes=wl.n_classes, max_children=wl.max_children, cfg=wl.grow)

    state = jax.eval_shape(
        lambda: frontier.init_state(prob,
                                    jnp.zeros((n_cases,), jnp.int32),
                                    jnp.ones((n_cases,), jnp.float32)))
    x = _sds((n_cases, wl.n_attrs), jnp.int32)
    y = _sds((n_cases,), jnp.int32)
    w = _sds((n_cases,), jnp.float32)
    cont = _sds((wl.n_attrs,), jnp.bool_)
    nb = _sds((wl.n_attrs,), jnp.int32)

    dp = part.batch_axes(mesh) + ("model",)   # cases over every axis (WS limit)
    case_sh = NamedSharding(mesh, P(dp))
    case2_sh = NamedSharding(mesh, P(dp, None))
    rep = part.replicated(mesh)
    state_sh = jax.tree.map(lambda _: rep, state)
    # case->node assignment lives with the cases
    state_sh = dataclasses.replace(state_sh, case_node=case_sh)

    def superstep(state, x, y, w, cont, nb):
        new_state, stats = frontier.superstep(state, x, y, w, cont, nb,
                                              prob=prob)
        return new_state, stats

    stats_sh = {k: rep for k in ("n_processed", "n_internal", "n_children",
                                 "max_r", "nap_nodes")}
    return Cell("yadt", shape, superstep,
                (state, x, y, w, cont, nb),
                (state_sh, case2_sh, case_sh, case_sh, rep, rep),
                (state_sh, stats_sh), {})


def lower_cell(cell: Cell, mesh: Mesh, *, unroll: bool = False, **knobs):
    import contextlib

    from repro.sharding import act
    from repro.utils import scan as uscan
    jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)
    ctx = uscan.unrolled() if unroll else contextlib.nullcontext()
    with mesh, act.from_mesh(mesh, **knobs), ctx:
        return jitted.lower(*cell.args)


def make_analysis_cells(arch: str, shape_name: str, mesh: Mesh
                        ) -> list[tuple[Cell, float]]:
    """Cells to lower *unrolled* for exact cost accounting + their scales.

    cost_analysis counts loop bodies once (see utils/scan.py).  Unrolling
    the whole train step is too slow to compile (>9 min/cell on this host),
    so costs are **composed from small unrolled pieces**, each compiling in
    seconds, scaled analytically:

      train:  n_cycles x [cycle_grad + cycle_fwd(remat recompute)]
              + tail_grad + tail_fwd + embed_grad + ce_grad + ce_fwd(remat)
              — all x grad_accum — + one optimizer step.
      prefill: n_cycles x cycle_fwd + tail_fwd + embed_fwd.
      decode / yadt: the production step itself (scan-free already).

    ZeRO all-gathers / grad reduce-scatters happen inside each piece, so the
    collective term composes identically.
    """
    from repro.models import layers as L
    from repro.models import transformer as T

    if arch == "yadt":
        return [(make_cell(arch, shape_name, mesh), 1.0)]   # scan-free step
    cfg = cfgbase.get_config(arch)
    shape = cfgbase.SHAPES[shape_name]
    if shape.kind == "decode":
        return [(make_cell(arch, shape_name, mesh), 1.0)]   # python loop

    model = build_model(cfg)
    pattern = cfg.block_pattern
    nc, rem = T.n_cycles(cfg)
    grad_accum = (4 if shape.kind == "train" and shape.global_batch >= 64
                  else 1)
    b_mb = shape.global_batch // grad_accum
    s = shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    params = jax.eval_shape(model.init, jax.random.key(0))
    params_sh = part.param_shardings(params, mesh)
    x_spec = _sds((b_mb, s, cfg.d_model), dt)
    x_sh = list(part.batch_shardings(mesh, {"x": x_spec}).values())[0]
    tokens = _sds((b_mb, s), jnp.int32)
    tok_sh = list(part.batch_shardings(mesh, {"t": tokens}).values())[0]
    labels_sh = tok_sh

    cells: list[tuple[Cell, float]] = []

    def group_cells(kinds, gparams, gparams_sh, tag):
        """fwd + (train-only) grad cells for a group of layers."""
        def fwd(cp, x):
            for j, kind in enumerate(kinds):
                x, _, _ = T._layer_full(cp[j], x, jnp.arange(s), cfg, kind,
                                        False)
            return x

        def grad(cp, x):
            return jax.grad(
                lambda c, xx: jnp.sum(fwd(c, xx).astype(jnp.float32)),
                argnums=(0, 1))(cp, x)

        out = [(Cell(arch, shape, fwd, (gparams, x_spec),
                     (gparams_sh, x_sh), x_sh, {}), None)]
        if shape.kind == "train":
            out.append((Cell(arch, shape, grad, (gparams, x_spec),
                             (gparams_sh, x_sh), (gparams_sh, x_sh), {}),
                        None))
        return out

    if nc:
        cyc_params = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
            params["scan"])
        cyc_sh = part.param_shardings(cyc_params, mesh)
        for cell, _ in group_cells(pattern, cyc_params, cyc_sh, "cycle"):
            cells.append((cell, float(grad_accum * nc)))
    if rem:
        tail_sh = part.param_shardings(params["tail"], mesh)
        for cell, _ in group_cells(pattern[:rem], params["tail"], tail_sh,
                                   "tail"):
            cells.append((cell, float(grad_accum)))

    # embedding (gather fwd + scatter-add bwd)
    fe = frontend_embeds_spec(cfg, b_mb)

    def embed_fwd(p, t, *rest):
        emb = T.embed_tokens(p, cfg, t, rest[0] if rest else None)
        return jnp.sum(emb.astype(jnp.float32))

    emb_args = [params, tokens] + ([fe] if fe is not None else [])
    emb_in_sh = [params_sh, tok_sh] + ([x_sh] if fe is not None else [])
    if shape.kind == "train":
        def embed_grad(p, t, *rest):
            return jax.grad(embed_fwd)(p, t, *rest)
        cells.append((Cell(arch, shape, embed_grad, tuple(emb_args),
                           tuple(emb_in_sh), params_sh, {}),
                      float(grad_accum)))
    else:
        cells.append((Cell(arch, shape, embed_fwd, tuple(emb_args),
                           tuple(emb_in_sh), part.replicated(mesh), {}),
                      float(grad_accum)))

    # final norm + chunked CE (train only; prefill's last-token unembed is
    # negligible next to the stack)
    if shape.kind == "train":
        from repro.models.model import chunked_cross_entropy

        def ce_loss(p, x, lab):
            h = L.norm_apply(p["final_norm"], x, cfg.norm)
            loss, _ = chunked_cross_entropy(
                h, lambda hh: T.unembed(p, cfg, hh), lab)
            return loss

        def ce_grad(p, x, lab):
            return jax.grad(ce_loss, argnums=(0, 1))(p, x, lab)

        rep = part.replicated(mesh)
        cells.append((Cell(arch, shape, ce_loss, (params, x_spec, tokens),
                           (params_sh, x_sh, labels_sh), rep, {}),
                      float(grad_accum)))          # remat recompute
        cells.append((Cell(arch, shape, ce_grad, (params, x_spec, tokens),
                           (params_sh, x_sh, labels_sh),
                           (params_sh, x_sh), {}),
                      float(grad_accum)))

        # optimizer step
        from repro.train import optimizer as optmod
        state = _state_specs(model, cfg)
        state_sh = _state_shardings(state, mesh)

        def opt_step(state, grads):
            p, m, v, _ = optmod.adamw_update(
                grads, state.m, state.v, state.params, state.step,
                optmod.AdamWConfig())
            return p, m, v

        cells.append((Cell(arch, shape, opt_step, (state, params),
                           (state_sh, params_sh),
                           (state_sh.params, state_sh.m, state_sh.v), {}),
                      1.0))
    return cells
