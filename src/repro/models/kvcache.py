"""Block-kind-aware serving cache + single-token decode step.

Cache layout per layer kind (B = batch, S = max sequence):

  global :  k, v        (B, S, KV, head_dim)    # seq-shardable (flash-decode)
  local  :  k, v        (B, window, KV, head_dim)  ring buffer, RoPE'd at write
  rwkv   :  state       (B, H, hd, hd) f32  + token-shift carries (B, D)
  rglru  :  h (B, W) f32 + conv window (B, conv_width-1, W)

``long_500k`` feasibility comes from this layout: only *global* layers hold
length-S state, and those are sequence-sharded across the mesh (the
softmax reductions in ``layers.decode_attention`` become psums under the
partitioner — distributed flash-decode).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, rglru, rwkv6, transformer

Cache = list[dict[str, jnp.ndarray]]


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Cache:
    dt = jnp.dtype(cfg.dtype)
    cache: Cache = []
    for i in range(cfg.n_layers):
        kind = cfg.block_kind(i)
        if kind == "global":
            shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
            cache.append({"k": jnp.zeros(shape, dt),
                          "v": jnp.zeros(shape, dt)})
        elif kind == "local":
            w = min(cfg.window, max_seq)
            shape = (batch, w, cfg.n_kv_heads, cfg.head_dim)
            cache.append({"k": jnp.zeros(shape, dt),
                          "v": jnp.zeros(shape, dt)})
        elif kind == "rwkv":
            hd = cfg.d_model // cfg.n_heads
            cache.append({
                "state": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
                "tm_prev": jnp.zeros((batch, cfg.d_model), dt),
                "cm_prev": jnp.zeros((batch, cfg.d_model), dt),
            })
        elif kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            cache.append({
                "h": jnp.zeros((batch, w), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dt),
            })
        else:
            raise ValueError(kind)
    return cache


def prefill_to_cache(cfg: ModelConfig, entries: list[dict],
                     cache: Cache, seq_len: int) -> Cache:
    """Merge forward(capture_cache=True) entries into a fresh cache."""
    out: Cache = []
    for i, (entry, slot) in enumerate(zip(entries, cache)):
        kind = cfg.block_kind(i)
        if kind == "global":
            k = jax.lax.dynamic_update_slice(
                slot["k"], entry["k"].astype(slot["k"].dtype), (0, 0, 0, 0))
            v = jax.lax.dynamic_update_slice(
                slot["v"], entry["v"].astype(slot["v"].dtype), (0, 0, 0, 0))
            out.append({"k": k, "v": v})
        elif kind == "local":
            w = slot["k"].shape[1]
            # entry already holds the last `window` tokens; place them so the
            # ring index (pos % window) lines up with absolute positions.
            n = entry["k"].shape[1]
            idx = (jnp.arange(seq_len - n, seq_len)) % w
            k = slot["k"].at[:, idx].set(entry["k"].astype(slot["k"].dtype))
            v = slot["v"].at[:, idx].set(entry["v"].astype(slot["v"].dtype))
            out.append({"k": k, "v": v})
        elif kind == "rwkv":
            out.append({"state": entry["state"],
                        "tm_prev": entry["tm_prev"].astype(slot["tm_prev"].dtype),
                        "cm_prev": entry["cm_prev"].astype(slot["cm_prev"].dtype)})
        elif kind == "rglru":
            out.append({"h": entry["h"].astype(jnp.float32),
                        "conv": entry["conv"].astype(slot["conv"].dtype)})
    return out


def _decode_attn_layer(lp, cfg: ModelConfig, kind: str, x: jnp.ndarray,
                       slot: dict, pos: jnp.ndarray):
    """pos: (B,) per-row position (continuous batching)."""
    spec = transformer.attn_spec(cfg, kind)
    b = x.shape[0]
    rows = jnp.arange(b)
    q, k, v = layers.qkv(lp["attn"], spec, x, pos[:, None])     # (B,1,·,·)
    if kind == "global":
        kc = slot["k"].at[rows, pos].set(k[:, 0].astype(slot["k"].dtype),
                                         mode="drop")
        vc = slot["v"].at[rows, pos].set(v[:, 0].astype(slot["v"].dtype),
                                         mode="drop")
        o = layers.decode_attention(q, kc, vc, pos, spec=spec)
    else:                                                       # local ring
        w = slot["k"].shape[1]
        ring = pos % w
        kc = slot["k"].at[rows, ring].set(k[:, 0].astype(slot["k"].dtype),
                                          mode="drop")
        vc = slot["v"].at[rows, ring].set(v[:, 0].astype(slot["v"].dtype),
                                          mode="drop")
        # Valid slots: the last min(pos+1, w) writes.  RoPE is baked in at
        # write time so ordering within the ring is irrelevant to the math.
        valid = jnp.arange(w)[None, :] <= jnp.minimum(pos, w - 1)[:, None]
        o = _ring_attention(q, kc, vc, valid, spec)
    x_attn = (o.reshape(b, 1, -1) @ lp["attn"]["wo"])
    return x_attn, {"k": kc, "v": vc}


def _ring_attention(q, k_ring, v_ring, valid, spec):
    """valid: (B, window) mask of live ring slots."""
    b, _, h, d = q.shape
    kv = k_ring.shape[2]
    g = h // kv
    qg = (q.reshape(b, kv, g, d) / jnp.sqrt(jnp.float32(d))
          ).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_ring.astype(jnp.float32))
    if spec.softcap > 0:
        logits = jnp.tanh(logits / spec.softcap) * spec.softcap
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_ring.astype(jnp.float32))
    o = o / jnp.sum(p, axis=-1)[..., None]
    return o.reshape(b, 1, h, d).astype(q.dtype)


def decode_step(params, cfg: ModelConfig, cache: Cache, token: jnp.ndarray,
                pos: jnp.ndarray):
    """One serving step: token (B, 1) + cache @ pos -> (logits, new cache).

    ``pos`` is scalar or (B,): per-row positions enable continuous batching
    (each slot advances at its own sequence index).
    """
    b = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    x = jnp.take(params["embed"], token, axis=0)                # (B, 1, D)
    if cfg.pos == "sinusoidal":
        x = x + layers.sinusoidal(pos, cfg.d_model)[:, None].astype(x.dtype)
    new_cache: Cache = []
    for i, slot in enumerate(cache):
        lp = transformer.layer_params(params, cfg, i)
        kind = cfg.block_kind(i)
        h = layers.norm_apply(lp["norm1"], x, cfg.norm)
        if kind in ("global", "local"):
            attn_out, new_slot = _decode_attn_layer(lp, cfg, kind, h, slot,
                                                    pos)
            x = x + attn_out
            y = layers.norm_apply(lp["norm2"], x, cfg.norm)
            f, _ = transformer._ffn(lp, cfg, y)
            x = x + f
        elif kind == "rwkv":
            spec = transformer.rwkv_spec(cfg)
            o, state, tm_prev = rwkv6.time_mix_step(
                lp["tm"], spec, h[:, 0], slot["state"],
                slot["tm_prev"].astype(h.dtype))
            x = x + o[:, None]
            y = layers.norm_apply(lp["norm2"], x, cfg.norm)
            cm = rwkv6.channel_mix(lp["tm"], spec, y,
                                   x_prev=slot["cm_prev"].astype(y.dtype))
            new_slot = {"state": state,
                        "tm_prev": tm_prev.astype(slot["tm_prev"].dtype),
                        "cm_prev": y[:, 0].astype(slot["cm_prev"].dtype)}
            x = x + cm
        elif kind == "rglru":
            spec = transformer.rglru_spec(cfg)
            o, h_new, conv = rglru.rglru_step(
                lp["rec"], spec, h[:, 0], slot["h"], slot["conv"])
            x = x + o[:, None]
            y = layers.norm_apply(lp["norm2"], x, cfg.norm)
            f, _ = transformer._ffn(lp, cfg, y)
            x = x + f
            new_slot = {"h": h_new, "conv": conv}
        new_cache.append(new_slot)
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    logits = transformer.unembed(params, cfg, x)[:, 0]          # (B, V)
    return logits, new_cache
