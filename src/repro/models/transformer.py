"""Composable decoder stack over heterogeneous block patterns.

One forward implementation serves every assigned architecture: each layer is
dispatched on its ``block_kind`` (global/local attention, rwkv, rglru), with
dense-MLP or MoE feed-forward.  Three modes share the same weights:

  mode="train"    full sequence, no cache (loss path; remat per cycle)
  mode="prefill"  full sequence, writes the serving cache
  mode="decode"   one token against the cache (see kvcache.decode_step)

**Scan-over-cycles**: layers are grouped into cycles of the architecture's
``block_pattern`` (e.g. gemma2's (local, global)); parameters of equal
pattern positions are stacked with a leading ``n_cycles`` axis and the whole
depth runs under one ``lax.scan``.  The HLO is O(cycle) instead of
O(n_layers) — this is what keeps the 60-layer/34B dry-run cells compilable —
and ``jax.checkpoint`` on the cycle body gives per-cycle remat.  Layers that
do not fill a whole cycle (gemma3: 34 = 5x6 + 4) live in a small unscanned
``tail``.

Param tree layout:

  {"embed": (V, D), "final_norm": ..., ["lm_head": (D, V)],
   "scan": tuple_j(stacked layer params, leading dim n_cycles),
   "tail": tuple(layer params)}
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import scan as uscan

from repro.configs.base import ModelConfig
from repro.models import layers, moe, rglru, rwkv6
from repro.models.layers import AttnSpec

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def attn_spec(cfg: ModelConfig, kind: str) -> AttnSpec:
    return AttnSpec(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        d_model=cfg.d_model, rope_theta=cfg.rope_theta,
        window=cfg.window if kind == "local" else 0,
        softcap=cfg.attn_softcap, use_rope=(cfg.pos == "rope"),
        dtype=_dtype(cfg))


def rwkv_spec(cfg: ModelConfig) -> rwkv6.RWKVSpec:
    return rwkv6.RWKVSpec(d_model=cfg.d_model, n_heads=cfg.n_heads,
                          d_ff=cfg.d_ff, dtype=_dtype(cfg))


def rglru_spec(cfg: ModelConfig) -> rglru.RGLRUSpec:
    return rglru.RGLRUSpec(d_model=cfg.d_model,
                           lru_width=cfg.lru_width or cfg.d_model,
                           conv_width=cfg.conv_width, dtype=_dtype(cfg))


def moe_spec(cfg: ModelConfig) -> moe.MoESpec:
    return moe.MoESpec(d_model=cfg.d_model, d_ff=cfg.d_ff,
                       n_experts=cfg.n_experts,
                       experts_per_token=cfg.experts_per_token,
                       n_shared_experts=cfg.n_shared_experts, act=cfg.act,
                       dtype=_dtype(cfg))


def n_cycles(cfg: ModelConfig) -> tuple[int, int]:
    p = len(cfg.block_pattern)
    return cfg.n_layers // p, cfg.n_layers % p


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, kind: str) -> Params:
    dt = _dtype(cfg)
    lk = jax.random.split(key, 3)
    lp: Params = {"norm1": layers.norm_init(cfg.d_model, cfg.norm, dt),
                  "norm2": layers.norm_init(cfg.d_model, cfg.norm, dt)}
    if kind in ("global", "local"):
        lp["attn"] = layers.attn_init(lk[0], attn_spec(cfg, kind))
    elif kind == "rwkv":
        lp["tm"] = rwkv6.rwkv_init(lk[0], rwkv_spec(cfg))
    elif kind == "rglru":
        lp["rec"] = rglru.rglru_init(lk[0], rglru_spec(cfg))
    else:
        raise ValueError(kind)
    if kind != "rwkv":                        # rwkv carries its channel-mix
        if cfg.is_moe:
            lp["moe"] = moe.moe_init(lk[1], moe_spec(cfg))
        else:
            lp["mlp"] = layers.mlp_init(lk[1], cfg.d_model, cfg.d_ff, dt)
    return lp


def init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    pattern = cfg.block_pattern
    nc, rem = n_cycles(cfg)
    k_embed, k_head, k_scan, k_tail = jax.random.split(key, 4)
    p: Params = {
        "embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": layers.norm_init(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(k_head, cfg.d_model,
                                         cfg.vocab_size, dt)

    def init_cycle(k):
        ks = jax.random.split(k, len(pattern))
        return tuple(_init_layer(ks[j], cfg, pattern[j])
                     for j in range(len(pattern)))

    p["scan"] = (jax.vmap(init_cycle)(jax.random.split(k_scan, nc))
                 if nc else ())
    p["tail"] = tuple(
        _init_layer(jax.random.fold_in(k_tail, j), cfg, pattern[j])
        for j in range(rem))
    return p


def layer_params(p: Params, cfg: ModelConfig, i: int) -> Params:
    """Per-layer view into the stacked tree (decode-path access)."""
    pat = len(cfg.block_pattern)
    nc, _ = n_cycles(cfg)
    c, j = divmod(i, pat)
    if c < nc:
        return jax.tree.map(lambda a: a[c], p["scan"][j])
    return p["tail"][j]


# --------------------------------------------------------------------------
# layer body (shared by scan / tail / prefill)
# --------------------------------------------------------------------------


def _ffn(lp: Params, cfg: ModelConfig, x: jnp.ndarray
         ) -> tuple[jnp.ndarray, dict]:
    if "moe" in lp:
        return moe.moe_apply(lp["moe"], x, moe_spec(cfg))
    return layers.mlp_apply(lp["mlp"], x, cfg.act), {}


def _layer_full(lp: Params, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, kind: str, capture: bool):
    """One decoder layer over the full sequence; optionally capture state."""
    aux: dict[str, jnp.ndarray] = {}
    cache_entry: dict[str, jnp.ndarray] = {}
    h = layers.norm_apply(lp["norm1"], x, cfg.norm)
    if kind in ("global", "local"):
        spec = attn_spec(cfg, kind)
        q, k, v = layers.qkv(lp["attn"], spec, h, positions)
        o = layers.blockwise_attention(q, k, v, spec=spec, q_offset=0)
        x = x + (o.reshape(*o.shape[:2], -1) @ lp["attn"]["wo"])
        if capture:
            from repro.sharding.act import shard_kv_capture
            if kind == "local":
                w = min(cfg.window, k.shape[1])
                cache_entry = {"k": k[:, -w:], "v": v[:, -w:]}
            else:
                cache_entry = {"k": shard_kv_capture(k),
                               "v": shard_kv_capture(v)}
        y = layers.norm_apply(lp["norm2"], x, cfg.norm)
        f, aux = _ffn(lp, cfg, y)
        x = x + f
    elif kind == "rwkv":
        if capture:
            o, state, x_last = rwkv6.time_mix(
                lp["tm"], rwkv_spec(cfg), h, return_state=True)
        else:
            o = rwkv6.time_mix(lp["tm"], rwkv_spec(cfg), h)
        x = x + o
        y = layers.norm_apply(lp["norm2"], x, cfg.norm)
        x = x + rwkv6.channel_mix(lp["tm"], rwkv_spec(cfg), y)
        if capture:
            cache_entry = {"state": state, "tm_prev": x_last,
                           "cm_prev": y[:, -1]}
    elif kind == "rglru":
        if capture:
            o, h_last, conv = rglru.rglru_apply(
                lp["rec"], rglru_spec(cfg), h, return_state=True)
            cache_entry = {"h": h_last, "conv": conv}
        else:
            o = rglru.rglru_apply(lp["rec"], rglru_spec(cfg), h)
        x = x + o
        y = layers.norm_apply(lp["norm2"], x, cfg.norm)
        f, aux = _ffn(lp, cfg, y)
        x = x + f
    return x, cache_entry, aux


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------


def embed_tokens(p: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                 frontend_embeds: jnp.ndarray | None = None) -> jnp.ndarray:
    from repro.sharding.act import shard_batch
    x = shard_batch(jnp.take(p["embed"], tokens, axis=0))
    if cfg.pos == "sinusoidal":
        pos = jnp.arange(tokens.shape[1])
        x = x + layers.sinusoidal(pos, cfg.d_model)[None].astype(x.dtype)
    if frontend_embeds is not None and cfg.frontend_tokens:
        n = cfg.frontend_tokens
        x = jnp.concatenate(
            [frontend_embeds[:, :n].astype(x.dtype), x[:, n:]], axis=1)
    return x


def forward(p: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds: jnp.ndarray | None = None, *,
            capture_cache: bool = False, remat: bool = True):
    """Full-sequence forward.  Returns (hidden, cache_entries, aux).

    ``cache_entries`` is a per-layer list in layer order (prefill only).
    """
    pattern = cfg.block_pattern
    nc, rem = n_cycles(cfg)
    x = embed_tokens(p, cfg, tokens, frontend_embeds)
    positions = jnp.arange(tokens.shape[1])

    def cycle(x, cp):
        from repro.sharding.act import shard_batch
        x = shard_batch(x)                  # re-anchor DP through the scan
        entries, auxes = [], []
        for j, kind in enumerate(pattern):
            x, e, a = _layer_full(cp[j], x, positions, cfg, kind,
                                  capture_cache)
            entries.append(e)
            auxes.append(a)
        return shard_batch(x), (tuple(entries), tuple(auxes))

    # prevent_cse=False: scan already provides the CSE barrier; without it
    # XLA hoists whole-stack dtype converts out of the backward loop
    # (empirically a 2x temp-memory regression).
    cycle_fn = jax.checkpoint(
        cycle, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False) if remat else cycle

    stacked_entries = None
    stacked_aux: tuple = ()
    if nc:
        x, (stacked_entries, stacked_aux) = uscan.scan(
            cycle_fn, x, p["scan"])

    entries: list[dict] = []
    if capture_cache and stacked_entries is not None:
        for c in range(nc):
            for j in range(len(pattern)):
                entries.append(jax.tree.map(lambda a, c=c: a[c],
                                            stacked_entries[j]))

    auxes: list[dict] = []
    for a in stacked_aux:
        if a:
            auxes.append({k: jnp.mean(v) for k, v in a.items()})

    for j in range(rem):
        x, e, a = _layer_full(p["tail"][j], x, positions, cfg, pattern[j],
                              capture_cache)
        if capture_cache:
            entries.append(e)
        if a:
            auxes.append(a)

    x = layers.norm_apply(p["final_norm"], x, cfg.norm)
    aux = {}
    if auxes:
        aux = {k: jnp.mean(jnp.stack([a[k] for a in auxes]))
               for k in auxes[0]}
    return x, entries, aux


def unembed(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = x @ w
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits
