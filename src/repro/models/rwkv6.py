"""RWKV-6 "Finch" time-mix block (arXiv:2404.05892) — attention-free LM.

The recurrence per head (state S in R^{d_k x d_v}):

    S_t   = diag(w_t) S_{t-1} + k_t^T v_t
    out_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with *data-dependent* per-channel decay  w_t = exp(-exp(ww_t)),
ww_t = w0 + LoRA(x_t) — the Finch signature — and token-shift mixing on all
branch inputs.

TPU adaptation: the sequential recurrence is re-blocked into a **chunked
scan** — within a chunk of L tokens the interaction is a dense (L, L)
decay-masked matmul (MXU work), across chunks a small state carry flows
through ``lax.scan``.  This is the standard linear-attention chunking that
turns an O(S) serial loop into O(S/L) steps of dense compute, and it is the
reason rwkv6 runs the ``long_500k`` shape with O(1) live state.

``step`` is the O(1) single-token path used by serve/decode.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import scan as uscan

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class RWKVSpec:
    d_model: int
    n_heads: int                      # head_dim = d_model // n_heads
    d_ff: int
    lora_rank: int = 64
    dtype: Any = jnp.bfloat16

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def rwkv_init(key, s: RWKVSpec) -> Params:
    ks = jax.random.split(key, 12)
    d, dt = s.d_model, s.dtype
    scale = 1.0 / math.sqrt(d)

    def lin(k, di, do):
        return (jax.random.normal(k, (di, do), jnp.float32) * scale
                ).astype(dt)

    return {
        # token-shift mix coefficients per branch (r, k, v, w, g)
        "mu": jnp.full((5, d), 0.5, dt),
        "wr": lin(ks[0], d, d), "wk": lin(ks[1], d, d),
        "wv": lin(ks[2], d, d), "wg": lin(ks[3], d, d),
        "wo": lin(ks[4], d, d),
        # decay: w0 + tanh(x A) B   (LoRA on the decay, per channel)
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wa": lin(ks[5], d, s.lora_rank).astype(jnp.float32),
        "wb": (jax.random.normal(ks[6], (s.lora_rank, d), jnp.float32)
               * 0.01),
        "u": jnp.zeros((d,), jnp.float32),      # bonus for current token
        "ln_out_scale": jnp.ones((s.n_heads, s.head_dim), jnp.float32),
        # channel-mix (classic RWKV FFN with shift)
        "cm_mu": jnp.full((2, d), 0.5, dt),
        "cm_k": lin(ks[7], d, s.d_ff),
        "cm_v": lin(ks[8], s.d_ff, d),
        "cm_r": lin(ks[9], d, d),
    }


def _shift(x: jnp.ndarray, prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """x_{t-1} along the sequence; ``prev`` seeds position 0 (decode carry)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _branches(p: Params, s: RWKVSpec, x: jnp.ndarray, xs: jnp.ndarray):
    mu = p["mu"].astype(jnp.float32)
    xf, xsf = x.astype(jnp.float32), xs.astype(jnp.float32)
    mix = [xf * mu[i] + xsf * (1 - mu[i]) for i in range(5)]
    r = (mix[0].astype(s.dtype) @ p["wr"]).astype(jnp.float32)
    k = (mix[1].astype(s.dtype) @ p["wk"]).astype(jnp.float32)
    v = (mix[2].astype(s.dtype) @ p["wv"]).astype(jnp.float32)
    ww = p["w0"] + jnp.tanh(mix[3] @ p["wa"].astype(jnp.float32)) @ p["wb"]
    w = jnp.exp(-jnp.exp(ww))                                  # decay in (0,1)
    g = jax.nn.silu(mix[4].astype(s.dtype) @ p["wg"])
    return r, k, v, w, g


def _heads(x: jnp.ndarray, h: int):
    b, seq, d = x.shape
    return x.reshape(b, seq, h, d // h)


def time_mix(p: Params, s: RWKVSpec, x: jnp.ndarray, *,
             chunk: int = 128, return_state: bool = False):
    """Full-sequence chunked evaluation (training / prefill).

    With ``return_state`` also returns (final_state, last_input) so prefill
    can seed the O(1) decode path.
    """
    b, seq, d = x.shape
    h, hd = s.n_heads, s.head_dim
    r, k, v, w, g = _branches(p, s, x, _shift(x))
    r, k, v, w = (_heads(t, h) for t in (r, k, v, w))          # (B,S,H,hd)
    u = p["u"].reshape(h, hd)

    chunk = min(chunk, seq)
    n_chunks = seq // chunk
    assert n_chunks * chunk == seq, "seq must divide by chunk"
    shape = (b, n_chunks, chunk, h, hd)
    rc, kc, vc, wc = (t.reshape(shape).transpose(1, 0, 3, 2, 4)
                      for t in (r, k, v, w))                   # (N,B,H,L,hd)

    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cum = jnp.cumsum(logw, axis=3)                             # prod_{s<=t} w
    # Clamp the within-chunk log-decay so exp(-cum) cannot overflow f32 when
    # trained decays get aggressive (log-space subchunking would be exact;
    # the clamp only bites when a channel forgets >e^30 within one chunk).
    cum = jnp.maximum(cum, -30.0)
    # intra-chunk: out_t += sum_{s<t} (r_t * prod_{s<u<=t} w_u) . k_s v_s
    #   decay(s->t) = exp(cum_t - cum_s - logw_t? ) — state applied *before*
    #   the bonus: S_{t-1} accumulates k_s v_s decayed by w_{s+1..t-1}... we
    #   fold via cum_{t-1} - cum_s  =  cum_t - logw_t - cum_s.
    ct = cum - logw                                            # cum_{t-1}

    def scan_chunk(state, inp):
        rc_, kc_, vc_, cum_, ct_, logw_ = inp                  # (B,H,L,·)
        l = rc_.shape[2]
        # inter-chunk: r_t · (decay(chunk_start->t-1) * S_prev)
        decay_in = jnp.exp(ct_)                                # (B,H,L,hd)
        out = jnp.einsum("bhld,bhdv->bhlv", rc_ * decay_in, state)
        # intra-chunk lower-triangular (s < t)
        a = jnp.einsum("bhld,bhsd->bhls",
                       rc_ * jnp.exp(ct_),
                       kc_ * jnp.exp(-cum_))
        tri = jnp.tril(jnp.ones((l, l), bool), k=-1)
        a = jnp.where(tri[None, None], a, 0.0)
        out = out + jnp.einsum("bhls,bhsv->bhlv", a, vc_)
        # current-token bonus u
        out = out + jnp.einsum("bhld,bhld,bhlv->bhlv",
                               rc_, u[None, :, None, :] * kc_, vc_)
        # state update to end of chunk:
        #   S = diag(prod w) S_prev + sum_s decay(s->L) k_s v_s
        total = cum_[:, :, -1:, :]                             # (B,H,1,hd)
        state = state * jnp.exp(total.squeeze(2))[..., None] + jnp.einsum(
            "bhsd,bhsv->bhdv", kc_ * jnp.exp(total - cum_), vc_)
        return state, out

    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    final_state, outs = uscan.scan(scan_chunk, s0,
                                   (rc, kc, vc, cum, ct, logw))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, seq, h, hd)

    # per-head groupnorm, then output gate + projection
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5) * p["ln_out_scale"]
    out = out.reshape(b, seq, d).astype(s.dtype) * g
    out = out @ p["wo"]
    if return_state:
        return out, final_state, x[:, -1]
    return out


def time_mix_step(p: Params, s: RWKVSpec, x: jnp.ndarray,
                  state: jnp.ndarray, x_prev: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(1) decode step.  x: (B, D); state: (B, H, hd, hd); x_prev: (B, D)."""
    b, d = x.shape
    h, hd = s.n_heads, s.head_dim
    r, k, v, w, g = _branches(p, s, x[:, None], x_prev[:, None])
    r, k, v, w = (t[:, 0].reshape(b, h, hd) for t in (r, k, v, w))
    u = p["u"].reshape(h, hd)
    kv = jnp.einsum("bhd,bhv->bhdv", k, v)
    out = jnp.einsum("bhd,bhdv->bhv", r, state + u[None, :, :, None] * kv)
    state = state * w[..., None] + kv
    mu_ = jnp.mean(out, -1, keepdims=True)
    var = jnp.var(out, -1, keepdims=True)
    out = (out - mu_) * jax.lax.rsqrt(var + 1e-5) * p["ln_out_scale"]
    out = out.reshape(b, d).astype(s.dtype) * g[:, 0]
    return out @ p["wo"], state, x


def channel_mix(p: Params, s: RWKVSpec, x: jnp.ndarray,
                x_prev: jnp.ndarray | None = None) -> jnp.ndarray:
    mu = p["cm_mu"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xs = _shift(x, None if x_prev is None else x_prev).astype(jnp.float32)
    xk = (xf * mu[0] + xs * (1 - mu[0])).astype(s.dtype)
    xr = (xf * mu[1] + xs * (1 - mu[1])).astype(s.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid(xr @ p["cm_r"]) * (k @ p["cm_v"])
