"""Assigned-architecture fleet: composable decoder blocks (dense GQA, MoE,
RWKV6, RG-LRU) behind one functional model API (see model.build_model)."""
