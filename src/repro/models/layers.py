"""Core decoder layers: norms, RoPE, blockwise GQA attention, gated MLP.

Pure-functional style: every layer is an ``init(key, ...) -> params`` plus an
``apply(params, x, ...)`` pair over plain dict pytrees — no framework
dependency, fully pjit/shard_map friendly.

Attention is *blockwise* (flash-style online softmax over KV chunks inside a
``lax.scan``): activation memory is O(S·chunk) instead of O(S²), which is
what lets the 32k-prefill shapes lower within a v5e's HBM, and it is
remat-friendly.  Local (sliding-window) masks, GQA, attn-logit softcapping
(gemma2) and RoPE are all handled here.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import scan as uscan

Params = dict[str, Any]

# --------------------------------------------------------------------------
# initialisation helpers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def norm_init(d: int, kind: str, dtype) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}        # gemma-style (1+scale)
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def norm_apply(p: Params, x: jnp.ndarray, kind: str,
               eps: float = 1e-6) -> jnp.ndarray:
    """Statistics accumulate in f32; the *apply* stays in the input dtype.

    Deliberately avoids ``x.astype(f32)`` on the residual stream: a
    standalone convert of the layer input lets XLA hoist a whole-stack
    bf16->f32 convert of the scan-saved carries out of the backward loop
    (observed +25 GB/device on the phi4 train cell).  The einsum with
    ``preferred_element_type=f32`` fuses the upcast into the reduction.
    """
    d = x.shape[-1]
    if kind == "rmsnorm":
        var = jnp.einsum("...d,...d->...", x, x,
                         preferred_element_type=jnp.float32) / d
        scale = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
        return x * scale * (1.0 + p["scale"]).astype(x.dtype)
    mu = (jnp.einsum("...d->...", x,
                     preferred_element_type=jnp.float32) / d)
    xc = x - mu[..., None].astype(x.dtype)
    var = jnp.einsum("...d,...d->...", xc, xc,
                     preferred_element_type=jnp.float32) / d
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return xc * inv * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


# --------------------------------------------------------------------------
# rotary / sinusoidal position embeddings
# --------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10_000.0) -> jnp.ndarray:
    """Apply RoPE. x: (B, S, H, D) with even D; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq    # (B, S, half)
    # cos/sin cast to the stream dtype *before* the multiply: a bf16 x f32
    # promotion would reintroduce the hoistable whole-tensor convert.
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def sinusoidal(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freq = 10_000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_model: int
    rope_theta: float = 10_000.0
    window: int = 0                 # 0 = global causal
    softcap: float = 0.0            # attention-logit softcap (gemma2)
    use_rope: bool = True
    dtype: Any = jnp.bfloat16


def attn_init(key, s: AttnSpec) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, s.d_model, s.n_heads * s.head_dim, s.dtype),
        "wk": dense_init(k2, s.d_model, s.n_kv_heads * s.head_dim, s.dtype),
        "wv": dense_init(k3, s.d_model, s.n_kv_heads * s.head_dim, s.dtype),
        "wo": dense_init(k4, s.n_heads * s.head_dim, s.d_model, s.dtype),
    }


def _softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(logits / cap) * cap if cap > 0 else logits


def qkv(p: Params, s: AttnSpec, x: jnp.ndarray, positions: jnp.ndarray):
    from repro.sharding.act import shard_batch
    b, sq, _ = x.shape
    q = (x @ p["wq"]).reshape(b, sq, s.n_heads, s.head_dim)
    k = (x @ p["wk"]).reshape(b, sq, s.n_kv_heads, s.head_dim)
    v = (x @ p["wv"]).reshape(b, sq, s.n_kv_heads, s.head_dim)
    q, k, v = shard_batch(q), shard_batch(k), shard_batch(v)
    if s.use_rope:
        q = rope(q, positions, s.rope_theta)
        k = rope(k, positions, s.rope_theta)
    return q, k, v


def _attn_mask(spec: AttnSpec, q_pos, k_pos, sk):
    mask = q_pos[:, None] >= k_pos[None, :]                  # causal
    if spec.window > 0:
        mask &= q_pos[:, None] - k_pos[None, :] < spec.window
    mask &= (k_pos < sk)[None, :]                            # padding
    # Barrier: stops XLA from hoisting the *broadcast* mask out of the
    # (q-block x kv-block) loops as an (nq, nk, B, KV, G, qc, kc) pred stack
    # (observed 6.4 GB/device in the train-cell backward).
    return jax.lax.optimization_barrier(mask)


def _causal_kv_range(spec: AttnSpec, qi, q_offset, q_chunk: int,
                     kv_chunk: int, nk: int):
    """Live KV-block range [lo, hi) for query block qi (causal frontier).

    Skipping fully-masked future blocks halves the S^2 attention work; a
    sliding window additionally drops blocks older than the window.  Works
    with traced qi (production fori_loop) and Python-int qi (unrolled
    analysis — exact triangular flop accounting).
    """
    py = isinstance(qi, int)
    q_end = q_offset + (qi + 1) * q_chunk - 1          # last query position
    hi = (min(int(q_end) // kv_chunk + 1, nk) if py
          else jnp.minimum(q_end // kv_chunk + 1, nk))
    if spec.window > 0:
        q_start = q_offset + qi * q_chunk
        lo_val = (q_start - spec.window + 1) // kv_chunk
        lo = max(int(lo_val), 0) if py else jnp.maximum(lo_val, 0)
    else:
        lo = 0 if py else jnp.int32(0)
    return lo, hi


def _flash_fwd(q, k, v, q_offset, *, spec: AttnSpec, q_chunk: int,
               kv_chunk: int, sk: int):
    """q: (nq,B,qc,KV,G,D) pre-scaled; k/v: (nk,B,ck,KV,D).

    Returns out (nq,B,qc,KV,G,D) and the per-row softmax stats (m, l).
    Only KV blocks inside the causal/window frontier are visited.
    """
    nq, b, qc, kv, g, d = q.shape
    nk = k.shape[0]

    def q_step(_, inputs):
        qi, q_blk = inputs
        q_pos = jnp.asarray(q_offset) + qi * q_chunk + jnp.arange(qc)
        qf = q_blk.astype(jnp.float32)

        def kv_body(ci, carry):
            m, l, acc = carry
            kci = jax.lax.dynamic_index_in_dim(k, ci, 0, keepdims=False)
            vci = jax.lax.dynamic_index_in_dim(v, ci, 0, keepdims=False)
            k_pos = ci * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum("bqkgd,bckd->bkgqc", qf,
                                kci.astype(jnp.float32))
            logits = _softcap(logits, spec.softcap)
            mask = _attn_mask(spec, q_pos, k_pos, sk)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bkgqc,bckd->bkgqd", p,
                                    vci.astype(jnp.float32)))
            return m_new, l_new, acc_new

        m0 = jnp.full((b, kv, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, d), jnp.float32)
        lo, hi = _causal_kv_range(spec, qi, q_offset, q_chunk,
                                  kv_chunk, nk)
        if isinstance(qi, int):                  # unrolled analysis path
            carry = (m0, l0, a0)
            for ci in range(int(lo), int(hi)):
                carry = kv_body(ci, carry)
            m, l, acc = carry
        else:
            m, l, acc = jax.lax.fori_loop(lo, hi, kv_body, (m0, l0, a0))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = out.transpose(0, 3, 1, 2, 4)                   # (B,qc,KV,G,D)
        return out.astype(q.dtype), m, l

    if uscan.is_unrolled():
        parts = [q_step(None, (qi, q[qi])) for qi in range(nq)]
        out = jnp.stack([p[0] for p in parts])
        m = jnp.stack([p[1] for p in parts])
        l = jnp.stack([p[2] for p in parts])
        return out, m, l

    def q_scan(_, inputs):
        return None, q_step(None, inputs)

    _, (out, m, l) = jax.lax.scan(q_scan, None, (jnp.arange(nq), q))
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, spec: AttnSpec, q_chunk: int, kv_chunk: int,
           sk: int, q_offset: int):
    out, _, _ = _flash_fwd(q, k, v, q_offset, spec=spec, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, sk=sk)
    return out


def _flash_vjp_fwd(q, k, v, spec, q_chunk, kv_chunk, sk, q_offset):
    out, m, l = _flash_fwd(q, k, v, q_offset, spec=spec, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, sk=sk)
    return out, (q, k, v, out, m, l)


def _flash_vjp_bwd(spec, q_chunk, kv_chunk, sk, q_offset, res, d_out):
    """FlashAttention-2 backward: recompute p per (q, kv) block.

    Outer loop over KV blocks emits (dk, dv) per block; the inner loop over
    q blocks accumulates dq in an f32 carry.  No stacked logits survive, and
    only blocks inside the causal/window frontier are visited (triangular
    iteration, mirroring the forward).
    """
    q, k, v, out, m, l = res
    nq, b, qc, kv, g, d = q.shape
    nk = k.shape[0]
    # D_i = rowsum(dO * O) per query row
    delta = jnp.einsum("nbqkgd,nbqkgd->nbkgq", d_out.astype(jnp.float32),
                       out.astype(jnp.float32))              # (nq,B,KV,G,qc)
    l_safe = jnp.maximum(l, 1e-30)

    def _q_range(ci):
        """Live q-block range [lo, hi) attending KV block ci."""
        py = isinstance(ci, int)
        off = q_offset
        lo_v = (ci * kv_chunk - off) // q_chunk
        lo = max(int(lo_v), 0) if py else jnp.maximum(lo_v, 0)
        if spec.window > 0:
            hi_v = ((ci + 1) * kv_chunk + spec.window - off - 2
                    ) // q_chunk + 1
            hi = min(int(hi_v), nq) if py else jnp.minimum(hi_v, nq)
        else:
            hi = nq if py else jnp.int32(nq)
        return lo, hi

    def kv_step(dq_acc, inp):
        ci, kci, vci = inp
        k_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        kf = kci.astype(jnp.float32)
        vf = vci.astype(jnp.float32)

        def q_body(qi, carry):
            dq_acc, dk, dv = carry
            idx = lambda a: jax.lax.dynamic_index_in_dim(a, qi, 0,
                                                         keepdims=False)
            q_blk, do_blk = idx(q), idx(d_out)
            m_i, l_i, delta_i = idx(m), idx(l_safe), idx(delta)
            q_pos = jnp.asarray(q_offset) + qi * q_chunk + jnp.arange(qc)
            qf = q_blk.astype(jnp.float32)
            dof = do_blk.astype(jnp.float32)
            raw = jnp.einsum("bqkgd,bckd->bkgqc", qf, kf)
            logits = _softcap(raw, spec.softcap)
            mask = _attn_mask(spec, q_pos, k_pos, sk)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            p = jnp.exp(logits - m_i[..., None]) / l_i[..., None]
            dp = jnp.einsum("bqkgd,bckd->bkgqc", dof, vf)
            dlog = p * (dp - delta_i[..., None])
            if spec.softcap > 0:
                dlog = dlog * (1.0 - jnp.square(
                    jnp.tanh(raw / spec.softcap)))
            dq_blk = jnp.einsum("bkgqc,bckd->bqkgd", dlog, kf)
            dk_new = dk + jnp.einsum("bkgqc,bqkgd->bckd", dlog, qf)
            dv_new = dv + jnp.einsum("bkgqc,bqkgd->bckd", p, dof)
            dq_acc = dq_acc.at[qi].add(dq_blk)
            return dq_acc, dk_new, dv_new

        dk0 = jnp.zeros((b, kv_chunk, kv, d), jnp.float32)
        dv0 = jnp.zeros((b, kv_chunk, kv, d), jnp.float32)
        lo, hi = _q_range(ci)
        if isinstance(ci, int):                 # unrolled analysis path
            carry = (dq_acc, dk0, dv0)
            for qi in range(int(lo), int(hi)):
                carry = q_body(qi, carry)
            dq_acc, dk, dv = carry
        else:
            dq_acc, dk, dv = jax.lax.fori_loop(lo, hi, q_body,
                                               (dq_acc, dk0, dv0))
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros(q.shape, jnp.float32)
    if uscan.is_unrolled():
        dq_acc = dq0
        dks, dvs = [], []
        for ci in range(nk):
            dq_acc, (dk_i, dv_i) = kv_step(dq_acc, (ci, k[ci], v[ci]))
            dks.append(dk_i)
            dvs.append(dv_i)
        dq, dk, dv = dq_acc, jnp.stack(dks), jnp.stack(dvs)
    else:
        dq, (dk, dv) = jax.lax.scan(kv_step, dq0,
                                    (jnp.arange(nk), k, v))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def blockwise_attention(
    q: jnp.ndarray,            # (B, Sq, H, D)
    k: jnp.ndarray,            # (B, Sk, KV, D)
    v: jnp.ndarray,            # (B, Sk, KV, D)
    *,
    spec: AttnSpec,
    q_offset: jnp.ndarray | int = 0,   # absolute position of q[0]
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jnp.ndarray:
    """Flash attention (fwd: online softmax; bwd: custom-VJP recompute).

    Live logits are one (B, KV, G, qc, kc) f32 block in either direction —
    this is what lets 32k-token prefill and 4k train cells fit HBM.  GQA
    folds query heads into (KV, group); causal/local/softcap masks included.
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)

    # Chunk size does not change total attention flops (all blocks are
    # computed either way), only peak memory — so analysis mode may grow it
    # to keep the unrolled graph small (see utils/scan.py).
    q_chunk = uscan.analysis_chunk(q_chunk, sq)
    kv_chunk = uscan.analysis_chunk(kv_chunk, sk)

    kv_chunk = min(kv_chunk, sk)
    nk = (sk + kv_chunk - 1) // kv_chunk
    pad_k = nk * kv_chunk - sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kc = k.reshape(b, nk, kv_chunk, kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, kv, d).transpose(1, 0, 2, 3, 4)

    q_chunk = min(q_chunk, sq)
    nq = (sq + q_chunk - 1) // q_chunk
    pad_q = nq * q_chunk - sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    qc = (q.reshape(b, nq, q_chunk, kv, g, d) * scale
          ).transpose(1, 0, 2, 3, 4, 5)

    out = _flash(qc, kc, vc, spec, q_chunk, kv_chunk, sk,
                 int(q_offset))                              # (nq,B,qc,KV,G,D)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq]


def decode_attention(
    q: jnp.ndarray,            # (B, 1, H, D)
    k_cache: jnp.ndarray,      # (B, S, KV, D)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,          # int32 (B,) per-row position of the new token
    *,
    spec: AttnSpec,
) -> jnp.ndarray:
    """Single-token attention against a (possibly seq-sharded) KV cache.

    Written as plain reductions over the cache's sequence axis so the SPMD
    partitioner turns the max/sum into psums when the cache is sequence-
    sharded (distributed flash-decode; see sharding/partitioning.py).
    ``pos`` is per batch row (continuous batching: slots at different
    positions decode in one step).
    """
    b, _, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    qg = (q.reshape(b, kv, g, d) / math.sqrt(d)).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg,
                        k_cache.astype(jnp.float32))
    logits = _softcap(logits, spec.softcap)
    k_pos = jnp.arange(s)
    mask = k_pos[None, :] <= pos[:, None]                     # (B, S)
    if spec.window > 0:
        mask &= k_pos[None, :] > (pos[:, None] - spec.window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    out = out / jnp.sum(p, axis=-1)[..., None]
    return out.reshape(b, 1, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# gated MLP
# --------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(p: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    from repro.sharding.act import shard_batch_tp_last
    a = x @ p["w_gate"]
    a = shard_batch_tp_last(a)               # (B, S, F): batch x DP, F x TP
    if act == "silu":
        a = jax.nn.silu(a.astype(jnp.float32)).astype(x.dtype)
    elif act == "gelu":
        a = jax.nn.gelu(a.astype(jnp.float32), approximate=True
                        ).astype(x.dtype)
    else:
        raise ValueError(act)
    return (a * (x @ p["w_up"])) @ p["w_down"]
