"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):

    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  # data-dependent decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: the linear recurrence is evaluated with
``jax.lax.associative_scan`` — log-depth tree scan, the canonical way to run
a diagonal LRU on a systolic machine (vs. the GPU kernel in the paper).
Wrapped in the Griffin block: causal conv1d(4) on the recurrent branch and a
GeLU gate branch, merged by elementwise product.

``step`` carries (h, conv window) for O(1) decode — this is why
recurrentgemma runs the ``long_500k`` shape.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]
_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_model: int
    lru_width: int
    conv_width: int = 4
    dtype: Any = jnp.bfloat16


def rglru_init(key, s: RGLRUSpec) -> Params:
    ks = jax.random.split(key, 6)
    scale = 1.0 / math.sqrt(s.d_model)

    def lin(k, di, do):
        return (jax.random.normal(k, (di, do), jnp.float32) * scale
                ).astype(s.dtype)

    # Lambda init so that a^c spreads decays across [0.9, 0.999] (paper)
    u = jax.random.uniform(ks[0], (s.lru_width,), jnp.float32, 0.9, 0.999)
    log_lambda = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1
    return {
        "w_in": lin(ks[1], s.d_model, s.lru_width),        # recurrent branch
        "w_gate_branch": lin(ks[2], s.d_model, s.lru_width),
        "w_out": lin(ks[3], s.lru_width, s.d_model),
        "conv_w": (jax.random.normal(ks[4], (s.conv_width, s.lru_width),
                                     jnp.float32) * 0.1).astype(s.dtype),
        "conv_b": jnp.zeros((s.lru_width,), s.dtype),
        "wa": lin(ks[5], s.lru_width, s.lru_width),
        "ba": jnp.zeros((s.lru_width,), jnp.float32),
        "wx": lin(jax.random.fold_in(key, 7), s.lru_width, s.lru_width),
        "bx": jnp.zeros((s.lru_width,), jnp.float32),
        "log_lambda": log_lambda,
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv1d. x: (B, S, W); w: (K, W)."""
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out + b


def _gates(p: Params, u: jnp.ndarray):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(uf @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -_C * jax.nn.softplus(p["log_lambda"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * uf)
    return a, gated


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t over axis 1, log-depth associative scan."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_apply(p: Params, s: RGLRUSpec, x: jnp.ndarray, *,
                return_state: bool = False):
    """Full-sequence Griffin recurrent block. x: (B, S, D) -> (B, S, D).

    With ``return_state`` also returns (h_last, conv_window) for decode.
    """
    u = x @ p["w_in"]                                          # (B, S, W)
    uc = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, gated = _gates(p, uc)
    h = rglru_scan(a, gated)                                   # (B, S, W)
    gate = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32),
                       approximate=True)
    out = (h * gate).astype(s.dtype) @ p["w_out"]
    if return_state:
        return out, h[:, -1], u[:, -(s.conv_width - 1):]
    return out


def rglru_step(p: Params, s: RGLRUSpec, x: jnp.ndarray,
               h_prev: jnp.ndarray, conv_state: jnp.ndarray
               ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(1) decode step.  x: (B, D); h_prev: (B, W); conv_state (B, K-1, W)."""
    u = x @ p["w_in"]                                          # (B, W)
    window = jnp.concatenate([conv_state, u[:, None]], axis=1)  # (B, K, W)
    uc = jnp.sum(window * p["conv_w"][None], axis=1) + p["conv_b"]
    a, gated = _gates(p, uc[:, None])
    h = a[:, 0] * h_prev + gated[:, 0]                         # (B, W)
    gate = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32),
                       approximate=True)
    out = (h * gate).astype(s.dtype) @ p["w_out"]
    return out, h, window[:, 1:]
