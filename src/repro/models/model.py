"""Public model API: ``build_model(cfg)`` -> init / loss / prefill / decode.

The loss path uses a *sequence-chunked, vocab-shardable* cross-entropy: the
(B, S, V) logits tensor is never materialised — per chunk the partial
logits are (B, chunk, V) with V on the 'model' mesh axis, and the logsumexp
reduction psums across vocab shards.  With 256k vocabs this is the
difference between fitting and not fitting HBM at train_4k.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.utils import scan as uscan

from repro.configs.base import ModelConfig
from repro.models import frontends, kvcache, transformer

IGNORE_ID = -100


def chunked_cross_entropy(x: jnp.ndarray, unembed_fn: Callable,
                          labels: jnp.ndarray, *, chunk: int = 512
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over non-ignored tokens, scanning over sequence chunks."""
    b, s, _ = x.shape
    chunk = uscan.analysis_chunk(chunk, s)   # flops-invariant (see scan.py)
    chunk = min(chunk, s)
    n = s // chunk
    assert n * chunk == s, "seq_len must divide by the CE chunk"
    xc = x.reshape(b, n, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def chunk_loss(xi, li):
        from repro.sharding.act import shard_batch_tp_last
        logits = shard_batch_tp_last(
            unembed_fn(xi).astype(jnp.float32))              # (B, c, V/tp)
        lse = jax.nn.logsumexp(logits, axis=-1)
        li_safe = jnp.maximum(li, 0)
        gold = jnp.take_along_axis(logits, li_safe[..., None],
                                   axis=-1)[..., 0]
        mask = (li != IGNORE_ID).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    # remat: the backward recomputes each chunk's logits instead of the scan
    # saving (n_chunks, B, c, V/tp) stacked f32 logits.
    chunk_loss_r = jax.checkpoint(
        chunk_loss, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False)

    def step(carry, inp):
        tot, cnt = carry
        l, n = chunk_loss_r(*inp)
        return (tot + l, cnt + n), None

    (tot, cnt), _ = uscan.scan(step, (jnp.float32(0), jnp.float32(0)),
                               (xc, lc))
    return tot / jnp.maximum(cnt, 1.0), cnt


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[..., tuple[jnp.ndarray, dict]]
    prefill: Callable[..., tuple[jnp.ndarray, list]]
    decode_step: Callable[..., tuple[jnp.ndarray, list]]
    init_cache: Callable[[int, int], list]


def build_model(cfg: ModelConfig) -> Model:
    def init(key):
        return transformer.init(key, cfg)

    def loss_fn(params, batch, *, remat: bool = True):
        tokens = batch["tokens"]
        labels = frontends.mask_frontend_labels(
            cfg, batch["labels"], IGNORE_ID)
        x, _, aux = transformer.forward(
            params, cfg, tokens, batch.get("frontend_embeds"),
            capture_cache=False, remat=remat)
        loss, n_tok = chunked_cross_entropy(
            x, lambda h: transformer.unembed(params, cfg, h), labels)
        metrics = dict(loss=loss, n_tokens=n_tok, **aux)
        if "moe_aux" in aux:
            loss = loss + 0.01 * aux["moe_aux"]
        return loss, metrics

    def prefill(params, tokens, frontend_embeds=None, *, max_seq=None):
        b, s = tokens.shape
        max_seq = max_seq or s
        x, entries, _ = transformer.forward(
            params, cfg, tokens, frontend_embeds, capture_cache=True,
            remat=False)
        cache = kvcache.init_cache(cfg, b, max_seq)
        cache = kvcache.prefill_to_cache(cfg, entries, cache, s)
        logits = transformer.unembed(params, cfg, x[:, -1:])[:, 0]
        return logits, cache

    def decode_step(params, cache, token, pos):
        return kvcache.decode_step(params, cfg, cache, token, pos)

    def init_cache(batch, max_seq):
        return kvcache.init_cache(cfg, batch, max_seq)

    return Model(cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill,
                 decode_step=decode_step, init_cache=init_cache)
