"""Mixture-of-Experts layer with gather-based dispatch (EP over 'model').

TPU adaptation notes (DESIGN.md §2 pattern — pick the parallelisation grain
analytically): the GShard one-hot dispatch einsum costs O(T·E·C·D) *counted*
MXU flops even though it moves one-hot data — it poisons both the roofline
and the useful-flops ratio.  We instead route with pure data movement:

  1. token top-k over router logits (standard softmax gating);
  2. per-expert **top-C token selection** on the routing scores — a fixed
     capacity C = ceil(T·k/E · capacity_factor); overflow tokens are dropped
     (their combine weight is 0), underflow slots are masked;
  3. ``take`` gathers (E, C, D) expert inputs, grouped-matmul FFN
     ``ecd,edf->ecf`` with expert-sharded weights, scatter-add combine.

Expert weights are (E, D, F) with E on the 'model' mesh axis, so the gather
materialises the all-to-all and the grouped matmul runs expert-parallel.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int
    n_experts: int
    experts_per_token: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    act: str = "silu"
    dtype: Any = jnp.bfloat16


def moe_init(key, s: MoESpec) -> Params:
    kg, k1, k2, k3, ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(s.d_model)
    p = {
        "router": (jax.random.normal(kg, (s.d_model, s.n_experts),
                                     jnp.float32) * scale),   # fp32 router
        "w_gate": (jax.random.normal(k1, (s.n_experts, s.d_model, s.d_ff),
                                     jnp.float32) * scale).astype(s.dtype),
        "w_up": (jax.random.normal(k2, (s.n_experts, s.d_model, s.d_ff),
                                   jnp.float32) * scale).astype(s.dtype),
        "w_down": (jax.random.normal(k3, (s.n_experts, s.d_ff, s.d_model),
                                     jnp.float32) * scale).astype(s.dtype),
    }
    if s.n_shared_experts:
        p["shared"] = layers.mlp_init(
            ks, s.d_model, s.d_ff * s.n_shared_experts, s.dtype)
    return p


def capacity(n_tokens: int, s: MoESpec) -> int:
    c = math.ceil(n_tokens * s.experts_per_token / s.n_experts
                  * s.capacity_factor)
    return min(max(8, c), n_tokens)


def moe_apply(p: Params, x: jnp.ndarray, s: MoESpec
              ) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """x: (B, S, D) -> (out, aux) with load-balancing auxiliary loss."""
    b, seq, d = x.shape
    t = b * seq
    xf = x.reshape(t, d)
    c = capacity(t, s)

    logits = xf.astype(jnp.float32) @ p["router"]              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, s.experts_per_token)   # (T, k)

    # combine weight of (token, expert): top-k gate prob, renormalised
    gate = jnp.zeros((t, s.n_experts), jnp.float32).at[
        jnp.arange(t)[:, None], top_e].set(
            top_p / jnp.sum(top_p, axis=-1, keepdims=True))

    # per-expert top-C token selection on the gate score
    score_te = gate.T                                          # (E, T)
    sel_score, sel_idx = jax.lax.top_k(score_te, c)            # (E, C)
    live = sel_score > 0.0                                     # dropped/empty

    from repro.sharding.act import shard_experts
    xg = jnp.take(xf, sel_idx.reshape(-1), axis=0
                  ).reshape(s.n_experts, c, d)                 # (E, C, D)
    xg = shard_experts(jnp.where(live[..., None], xg, 0).astype(s.dtype))

    a = shard_experts(jnp.einsum("ecd,edf->ecf", xg, p["w_gate"]))
    a = jax.nn.silu(a.astype(jnp.float32)).astype(s.dtype) if s.act == "silu" \
        else jax.nn.gelu(a.astype(jnp.float32)).astype(s.dtype)
    h = a * jnp.einsum("ecd,edf->ecf", xg, p["w_up"])
    y = shard_experts(jnp.einsum("ecf,efd->ecd", h, p["w_down"]))  # (E,C,D)

    y = (y.astype(jnp.float32) * sel_score[..., None]
         * live[..., None])                                    # gate-weighted
    out = jnp.zeros((t, d), jnp.float32).at[
        sel_idx.reshape(-1)].add(y.reshape(-1, d), mode="drop")

    if s.n_shared_experts:
        out = out + layers.mlp_apply(p["shared"], xf, s.act
                                     ).astype(jnp.float32)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e[:, 0], s.n_experts), axis=0)
        / t)
    frac = jnp.sum(jax.nn.one_hot(top_e, s.n_experts), axis=(0, 1)) / (
        t * s.experts_per_token)
    aux = s.n_experts * jnp.sum(me * frac)
    stats = dict(moe_aux=aux,
                 moe_dropped=1.0 - jnp.mean(live.astype(jnp.float32)))
    del ce
    return out.reshape(b, seq, d).astype(x.dtype), stats
