"""Modality frontend STUBS (per the assignment brief).

``[vlm]``/``[audio]`` entries specify the transformer *backbone* only; the
modality frontend supplies precomputed embeddings:

  vision — anyres patch embeddings (B, frontend_tokens, d_model), early-fused
           into the first ``frontend_tokens`` sequence positions (llava-next
           style).  A real deployment swaps in the CLIP tower + projector.
  audio  — EnCodec: the token stream itself *is* the audio codes (musicgen is
           decoder-only over EnCodec tokens, vocab 2048); an optional frame-
           embedding tensor is accepted for conditioning stubs.

These helpers only produce test/dry-run inputs with the right shapes/dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def frontend_embeds_spec(cfg: ModelConfig, batch: int):
    if not cfg.frontend or not cfg.frontend_tokens:
        return None
    return jax.ShapeDtypeStruct((batch, cfg.frontend_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))


def fake_frontend_embeds(cfg: ModelConfig, batch: int, seed: int = 0):
    spec = frontend_embeds_spec(cfg, batch)
    if spec is None:
        return None
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 0.02, spec.shape), spec.dtype)


def mask_frontend_labels(cfg: ModelConfig, labels: jnp.ndarray,
                         ignore_id: int = -100) -> jnp.ndarray:
    """Loss-mask the positions occupied by frontend embeddings."""
    if not cfg.frontend_tokens:
        return labels
    n = cfg.frontend_tokens
    return labels.at[:, :n].set(ignore_id)
