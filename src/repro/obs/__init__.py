"""Observability layer: span tracing, metrics, and text reports.

The paper's evidence is *measured* runtime behaviour — emitter utilisation,
per-worker queue occupancy, weighted-load balance (Fig. 13/14) and the
NP/NAP decision statistics (Fig. 15).  This package is the unified way the
repo's three runtimes expose that data:

  :mod:`repro.obs.trace`    — thread-safe span tracer; exports Chrome
                              trace-event JSON loadable in Perfetto
                              (https://ui.perfetto.dev).
  :mod:`repro.obs.metrics`  — process-wide registry of labelled counters,
                              gauges and histograms.
  :mod:`repro.obs.report`   — text summary renderer (phase breakdowns,
                              queued-weight timelines, latency histograms).

Instrumented producers: the supervised farm (:mod:`repro.core.farm`), the
SPMD frontier engine (:func:`repro.core.frontier.build` with
``collect_stats``/``tracer``), the serving engine
(:mod:`repro.serve.engine`) and the heartbeat plane
(:mod:`repro.train.elastic`).  Everything is zero-cost when tracing is
disabled: the default :data:`repro.obs.trace.NULL` tracer short-circuits
every call.
"""

from repro.obs.metrics import REGISTRY, Registry  # noqa: F401
from repro.obs.trace import NULL, Tracer  # noqa: F401
