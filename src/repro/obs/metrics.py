"""Process-wide registry of labelled counters, gauges and histograms.

A deliberately small, dependency-free metrics core (the shape follows the
Prometheus client model):

  * :class:`Counter`   — monotonically increasing totals
    (``farm_events_total{event="retry"}``);
  * :class:`Gauge`     — last-written values
    (``frontier_active_cases``, ``heartbeat_hosts_alive``);
  * :class:`Histogram` — bucketed distributions with sum/count
    (``engine_queue_wait_ticks``).

Every metric takes free-form keyword labels per observation; each distinct
label combination is its own series.  :data:`REGISTRY` is the process-wide
default written to by the instrumented runtimes; benchmarks and tests may
pass their own :class:`Registry` for isolation.  ``snapshot()`` returns a
plain-JSON structure (committed next to ``BENCH_*`` baselines and diffed
by ``benchmarks/check_regression.py``).
"""

from __future__ import annotations

import threading
from typing import Any, Iterable

#: Default histogram buckets: log-ish ladder wide enough for both seconds
#: (kernel phases) and ticks (engine latencies).
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5,
                   1.0, 5.0, 10.0, 50.0, 100.0, 500.0, 1000.0)


def _key(labels: dict[str, Any]) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Metric:
    kind = "metric"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: dict[tuple, Any] = {}

    def labels_of(self) -> list[dict]:
        with self._lock:
            return [dict(k) for k in self._series]

    def _snapshot_series(self) -> list[dict]:
        raise NotImplementedError


class Counter(Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        k = _key(labels)
        with self._lock:
            self._series[k] = self._series.get(k, 0.0) + amount

    def value(self, **labels: Any) -> float:
        with self._lock:
            return self._series.get(_key(labels), 0.0)

    def _snapshot_series(self) -> list[dict]:
        with self._lock:
            return [{"labels": dict(k), "value": v}
                    for k, v in self._series.items()]


class Gauge(Metric):
    kind = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        with self._lock:
            self._series[_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        k = _key(labels)
        with self._lock:
            self._series[k] = self._series.get(k, 0.0) + amount

    def value(self, **labels: Any) -> float:
        with self._lock:
            return self._series.get(_key(labels), 0.0)

    def _snapshot_series(self) -> list[dict]:
        with self._lock:
            return [{"labels": dict(k), "value": v}
                    for k, v in self._series.items()]


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError(f"histogram {self.name}: needs >= 1 bucket")

    def observe(self, value: float, **labels: Any) -> None:
        k = _key(labels)
        with self._lock:
            st = self._series.get(k)
            if st is None:
                st = self._series[k] = {
                    "counts": [0] * (len(self.buckets) + 1),
                    "sum": 0.0, "count": 0}
            i = len(self.buckets)                     # +inf overflow bucket
            for j, le in enumerate(self.buckets):
                if value <= le:
                    i = j
                    break
            st["counts"][i] += 1
            st["sum"] += value
            st["count"] += 1

    def quantile(self, q: float, **labels: Any) -> float:
        """Bucket-upper-bound estimate of the q-quantile (q in [0, 1])."""
        with self._lock:
            st = self._series.get(_key(labels))
            if st is None or not st["count"]:
                return float("nan")
            rank = q * st["count"]
            seen = 0
            for j, n in enumerate(st["counts"]):
                seen += n
                if seen >= rank and n:
                    return (self.buckets[j] if j < len(self.buckets)
                            else float("inf"))
            return float("inf")

    def _snapshot_series(self) -> list[dict]:
        with self._lock:
            return [{"labels": dict(k), "buckets": list(self.buckets),
                     "counts": list(st["counts"]), "sum": st["sum"],
                     "count": st["count"]}
                    for k, st in self._series.items()]


class Registry:
    """Named metric store; getters are idempotent and kind-checked."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, Metric] = {}

    def _get(self, cls, name: str, help: str, **kw: Any) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Metric | None:
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self) -> dict[str, dict]:
        """JSON-able view of every metric: kind, help, per-label series."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: {"kind": m.kind, "help": m.help,
                         "series": m._snapshot_series()}
                for m in metrics}

    def reset(self) -> None:
        """Drop every registered metric (tests / fresh benchmark runs)."""
        with self._lock:
            self._metrics.clear()


#: Process-wide default registry: the instrumented runtimes write here
#: unless handed an explicit one.
REGISTRY = Registry()
