"""Thread-safe span tracer with Chrome-trace-event JSON export.

Produces the `Trace Event Format`_ consumed by Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing``:

  * :meth:`Tracer.span` — nestable duration spans (``ph="X"``); nesting is
    per-thread, so farm workers show up as separate lanes;
  * :meth:`Tracer.instant` — point events (retries, evictions, deaths);
  * :meth:`Tracer.counter` — numeric time series (per-worker queued
    weight), rendered by Perfetto as a stacked timeline;
  * :meth:`Tracer.begin` / :meth:`Tracer.end` — async spans that may cross
    threads and overlap (one per serving request, keyed by uid).

Zero-cost when disabled: every method checks ``self.enabled`` first and
returns a shared no-op, so instrumented hot paths (the farm worker loop,
the engine tick) pay one attribute load + branch.  :data:`NULL` is the
process-wide disabled tracer used as the default everywhere.

.. _Trace Event Format:
   https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any


class _NullSpan:
    """Shared no-op context manager returned by a disabled tracer."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One open duration span; emits a single complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._now_us()
        return self

    def __exit__(self, *exc: Any) -> bool:
        tr = self._tracer
        t1 = tr._now_us()
        ev = {"name": self._name, "ph": "X", "ts": self._t0,
              "dur": t1 - self._t0, "pid": tr._pid, "tid": tr._tid()}
        if self._args:
            ev["args"] = self._args
        tr._emit(ev)
        return False


class Tracer:
    """Collects trace events in memory; thread-safe; export via :meth:`save`.

    ``enabled=False`` turns every call into a cheap no-op — construct one
    tracer per run you want to inspect and pass it down; the default
    everywhere is the disabled :data:`NULL`.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._pid = os.getpid()
        self._tid_map: dict[int, int] = {}

    # ----------------------------------------------------------- internals
    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tid_map.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tid_map.setdefault(ident, len(self._tid_map) + 1)
                self._events.append({
                    "name": "thread_name", "ph": "M", "pid": self._pid,
                    "tid": tid,
                    "args": {"name": threading.current_thread().name}})
        return tid

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    # ------------------------------------------------------------- emitters
    def span(self, name: str, **args: Any):
        """Context manager timing a nested duration span on this thread."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args or None)

    def instant(self, name: str, **args: Any) -> None:
        """A point event (``ph="i"``): retries, evictions, deaths, ..."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "t", "ts": self._now_us(),
              "pid": self._pid, "tid": self._tid()}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, **values: float) -> None:
        """A counter sample (``ph="C"``): Perfetto draws a value timeline."""
        if not self.enabled:
            return
        self._emit({"name": name, "ph": "C", "ts": self._now_us(),
                    "pid": self._pid, "tid": self._tid(), "args": values})

    def begin(self, name: str, id: int, **args: Any) -> None:
        """Open an async span (``ph="b"``) — may overlap and cross threads."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": "async", "ph": "b", "id": id,
              "ts": self._now_us(), "pid": self._pid, "tid": self._tid()}
        if args:
            ev["args"] = args
        self._emit(ev)

    def end(self, name: str, id: int, **args: Any) -> None:
        """Close the async span opened by :meth:`begin` with the same id."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": "async", "ph": "e", "id": id,
              "ts": self._now_us(), "pid": self._pid, "tid": self._tid()}
        if args:
            ev["args"] = args
        self._emit(ev)

    # ------------------------------------------------------------ consumers
    @property
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def to_chrome(self) -> dict:
        """The JSON-object trace form Perfetto/chrome://tracing load."""
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path

    def span_summary(self) -> dict[str, dict[str, float]]:
        """Aggregate duration spans by name: count/total/mean/max (us)."""
        out: dict[str, dict[str, float]] = {}
        for ev in self.events:
            if ev.get("ph") != "X":
                continue
            s = out.setdefault(ev["name"],
                               {"count": 0, "total_us": 0.0, "max_us": 0.0})
            s["count"] += 1
            s["total_us"] += ev["dur"]
            s["max_us"] = max(s["max_us"], ev["dur"])
        for s in out.values():
            s["mean_us"] = s["total_us"] / max(s["count"], 1)
        return out

    def counter_series(self) -> dict[str, list[tuple[float, dict]]]:
        """Counter samples grouped by name as ``[(ts_us, values), ...]``."""
        out: dict[str, list[tuple[float, dict]]] = {}
        for ev in self.events:
            if ev.get("ph") == "C":
                out.setdefault(ev["name"], []).append((ev["ts"], ev["args"]))
        for series in out.values():
            series.sort(key=lambda p: p[0])
        return out


#: Process-wide disabled tracer — the default for every instrumented path.
NULL = Tracer(enabled=False)
