"""Text summary renderer for a traced/metered run.

``render(tracer=..., metrics=..., farm_stats=...)`` produces the human
"where did the time go" view the paper's figures are built from:

  * span breakdown — per-name count/total/mean/max, with the superstep
    phases (``splitPre``/``splitAtt``/``splitPost``) as ordinary rows;
  * counter timelines — unicode sparklines of ``ph="C"`` series, e.g. the
    per-worker queued-weight trajectory behind Fig. 13's balance argument;
  * metrics — counters and gauges as lines, histograms as bar charts with
    p50/p90/p99 (request queue-wait and decode latency);
  * farm stats — emitter-busy %, per-worker busy seconds and task counts
    (paper Fig. 14's execution breakdown) straight from ``Farm.stats()``.

Everything degrades gracefully: sections with no data are omitted.
"""

from __future__ import annotations

from typing import Any

_SPARK = "▁▂▃▄▅▆▇█"


def _sparkline(values: list[float], width: int = 48) -> str:
    if not values:
        return ""
    if len(values) > width:                      # downsample by striding
        step = len(values) / width
        values = [values[int(i * step)] for i in range(width)]
    lo, hi = min(values), max(values)
    span = (hi - lo) or 1.0
    return "".join(_SPARK[int((v - lo) / span * (len(_SPARK) - 1))]
                   for v in values)


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:8.3f}s "
    if us >= 1e3:
        return f"{us / 1e3:8.2f}ms"
    return f"{us:8.1f}us"


def _span_section(tracer) -> list[str]:
    summary = tracer.span_summary()
    if not summary:
        return []
    wall = 0.0
    for ev in tracer.events:
        if ev.get("ph") == "X":
            wall = max(wall, ev["ts"] + ev["dur"])
    lines = ["== spans ==",
             f"{'name':<28}{'count':>7}{'total':>11}{'mean':>11}"
             f"{'max':>11}{'%wall':>7}"]
    for name, s in sorted(summary.items(),
                          key=lambda kv: -kv[1]["total_us"]):
        pct = 100.0 * s["total_us"] / wall if wall else 0.0
        lines.append(f"{name:<28}{s['count']:>7.0f}"
                     f"{_fmt_us(s['total_us']):>11}"
                     f"{_fmt_us(s['mean_us']):>11}"
                     f"{_fmt_us(s['max_us']):>11}{pct:>6.1f}%")
    return lines


def _counter_section(tracer) -> list[str]:
    series = tracer.counter_series()
    if not series:
        return []
    lines = ["", "== counter timelines =="]
    for name, points in sorted(series.items()):
        for field in sorted({k for _, vals in points for k in vals}):
            vals = [v[field] for _, v in points if field in v]
            label = name if field in ("value", "weight") else f"{name}.{field}"
            lines.append(f"{label:<28}last={vals[-1]:<10.4g}"
                         f"max={max(vals):<10.4g}{_sparkline(vals)}")
    return lines


def _histogram_lines(name: str, s: dict, width: int = 30) -> list[str]:
    counts, buckets = s["counts"], s["buckets"]
    total = s["count"]
    if not total:
        return []
    label = ",".join(f"{k}={v}" for k, v in sorted(s["labels"].items()))
    mean = s["sum"] / total

    def q(frac: float) -> str:
        rank, seen = frac * total, 0
        for j, n in enumerate(counts):
            seen += n
            if seen >= rank and n:
                return f"{buckets[j]:g}" if j < len(buckets) else "inf"
        return "inf"

    head = (f"{name}{{{label}}}" if label else name)
    lines = [f"{head}  count={total} mean={mean:.4g} "
             f"p50<={q(.5)} p90<={q(.9)} p99<={q(.99)}"]
    peak = max(counts)
    for j, n in enumerate(counts):
        if not n:
            continue
        le = f"<= {buckets[j]:g}" if j < len(buckets) else "> last"
        bar = "#" * max(1, int(n / peak * width))
        lines.append(f"  {le:>12} {bar} {n}")
    return lines


def _metrics_section(metrics) -> list[str]:
    snap = metrics.snapshot() if metrics is not None else {}
    if not snap:
        return []
    lines = ["", "== metrics =="]
    for name, m in sorted(snap.items()):
        if m["kind"] == "histogram":
            for s in m["series"]:
                lines.extend(_histogram_lines(name, s))
            continue
        for s in m["series"]:
            label = ",".join(f"{k}={v}"
                             for k, v in sorted(s["labels"].items()))
            head = f"{name}{{{label}}}" if label else name
            lines.append(f"{head:<44}{s['value']:g}")
    return lines


def _farm_section(stats: dict[str, Any]) -> list[str]:
    if not stats:
        return []
    busy = stats.get("worker_busy", [])
    tasks = stats.get("worker_tasks", [])
    dead = set(stats.get("dead_workers", []))
    total_busy = sum(busy) or 1.0
    wall = max(busy) if busy else 0.0
    emitter = stats.get("emitter_busy", 0.0)
    pct = 100.0 * emitter / wall if wall else 0.0
    lines = ["", "== farm ==",
             f"emitter busy {emitter:.4f}s ({pct:.1f}% of the longest "
             f"worker lane)"]
    for i, b in enumerate(busy):
        n = tasks[i] if i < len(tasks) else 0
        mark = " DEAD" if i in dead else ""
        bar = "#" * max(1, int(b / total_busy * 40)) if b > 0 else ""
        lines.append(f"  w{i:<3} {b:8.4f}s {n:>6} tasks {bar}{mark}")
    for k in ("failures", "retries", "requeues", "timeouts",
              "quarantined", "dropped_late"):
        if stats.get(k):
            lines.append(f"  {k}: {stats[k]}")
    return lines


def render(tracer=None, metrics=None, farm_stats: dict | None = None) -> str:
    """One text report over whatever sources are provided."""
    lines: list[str] = []
    if tracer is not None:
        lines += _span_section(tracer)
        lines += _counter_section(tracer)
    lines += _metrics_section(metrics)
    if farm_stats:
        lines += _farm_section(farm_stats)
    return "\n".join(lines) if lines else "(no observability data)"
