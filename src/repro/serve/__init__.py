"""Serving substrate: slot-batched engine + WS request scheduling."""
