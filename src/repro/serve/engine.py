"""Slot-batched serving engine with WS request scheduling and failover.

The paper's farm is applied here as a *runtime feature* (DESIGN.md §5): a
fleet of model replicas is a farm; requests are tasks whose weight is
``len(prompt) + max_new_tokens`` — the total token work the request will
occupy a slot for, prefill plus decode (the serving analogue of weight = r
cases at a node); the emitter assigns each request to the replica with the
least outstanding weighted work — FastFlow's ``ws_scheduler`` verbatim,
from :mod:`repro.core.scheduler`.  Any of the paper's policies can be
selected by name (``drr | od | ws | health_ws``); ``od`` admits at most
``Policy.forced_capacity`` (= 1) newly-queued requests per replica per
tick, and admission always considers the *full* replica list with evicted
replicas masked as zero-capacity, so round-robin state never drifts across
a failover.

Each replica runs **continuous batching** over a fixed number of cache
slots: one jitted ``decode_step`` advances every active slot per tick;
prompts are prefilled into free slots (batch-1 prefill merged into the slot
axis); finished sequences free their slot immediately.

The engine is additionally **fault-tolerant** (see README "Fault model"):

  * a replica whose ``tick``/``admit`` raises is *evicted* — marked
    unhealthy, never scheduled again — and its in-flight requests are
    re-admitted to the backlog (bounded by ``max_requeues``; a request over
    budget becomes an explicit :class:`RequestFailure`);
  * replica liveness can also be driven by a
    :class:`~repro.train.elastic.HeartbeatMonitor` measured in engine ticks
    (``heartbeat_ticks``): the engine beats host ``"replica{i}"`` on every
    successful tick and evicts replicas the monitor declares failed;
  * per-request deadlines (``Request.deadline_ticks``, measured from
    submission) cancel the slot and surface a ``"timeout"`` failure with
    the partial decode;
  * ``run_until_drained`` accounts for **every** submitted request: each
    ends as exactly one :class:`Completion` or one :class:`RequestFailure`
    (``engine.failed``) — hitting ``max_ticks`` or losing the last replica
    produces explicit failure records, never a silently dropped request.

Scheduler races on admission (``Replica.admit`` finding no free slot) are
absorbed by requeueing the request rather than crashing the engine loop.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import Policy, QueueState, make_policy
from repro.models.model import Model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.sampling import sample
from repro.train.elastic import HeartbeatMonitor


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    deadline_ticks: int | None = None   # budget in engine ticks, from submit

    @property
    def weight(self) -> float:
        return float(len(self.prompt) + self.max_new_tokens)


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: list[int]


@dataclasses.dataclass
class RequestFailure:
    """Explicit terminal record for a request that did not complete."""

    uid: int
    reason: str                 # timeout | replica_dead | requeue_exhausted |
                                # no_replicas | max_ticks
    detail: str = ""
    tokens: list = dataclasses.field(default_factory=list)   # partial decode


class Replica:
    """One model replica: fixed slot batch + shared cache."""

    def __init__(self, model: Model, params: Any, *, n_slots: int,
                 max_seq: int, seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = model.init_cache(n_slots, max_seq)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.pos = np.zeros(n_slots, np.int64)            # next write index
        self.remaining = np.zeros(n_slots, np.int64)
        self.active = np.zeros(n_slots, bool)
        self.uid = np.full(n_slots, -1, np.int64)
        self.out: dict[int, list[int]] = {}
        self.key = jax.random.key(seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, t, max_seq=max_seq))

    # -- WorkerView for the WS policy ---------------------------------------
    def queue_len(self) -> int:
        return int(self.active.sum())

    def queued_weight(self) -> float:
        return float(self.remaining[self.active].sum())

    def capacity(self) -> int:
        return self.n_slots

    # -- failover introspection ----------------------------------------------
    def active_uids(self) -> list[int]:
        return [int(u) for u in self.uid[self.active]]

    def release(self, uid: int) -> list[int]:
        """Cancel a request's slot; returns its partial decode."""
        for s in range(self.n_slots):
            if self.active[s] and int(self.uid[s]) == uid:
                self.active[s] = False
                self.uid[s] = -1
                return self.out.pop(uid, [])
        return self.out.pop(uid, [])

    # -- admission -----------------------------------------------------------
    def admit(self, req: Request) -> None:
        free = np.flatnonzero(~self.active)
        if not free.size:
            raise RuntimeError("no free slot (scheduler race)")
        s = int(free[0])
        logits, cache1 = self._prefill(self.params,
                                       jnp.asarray(req.prompt)[None])
        # splice the batch-1 prefill cache into slot s of the shared cache
        self.cache = jax.tree.map(
            lambda big, one: big.at[s:s + 1].set(one.astype(big.dtype)),
            self.cache, _pad_cache_seq(cache1, self.cache))
        tok = int(jnp.argmax(logits, -1)[0])
        self.tokens = self.tokens.at[s, 0].set(tok)
        self.pos[s] = len(req.prompt)
        self.remaining[s] = req.max_new_tokens - 1
        self.active[s] = True
        self.uid[s] = req.uid
        self.out[req.uid] = [tok]

    # -- one decode tick over all active slots -------------------------------
    def tick(self) -> list[Completion]:
        if not self.active.any():
            return []
        # Per-slot positions: every active slot advances at its own index
        # (continuous batching); the decode step masks per row.
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, pos_vec)
        self.key, sub = jax.random.split(self.key)
        nxt = np.asarray(sample(logits, sub))
        done: list[Completion] = []
        for s in range(self.n_slots):
            if not self.active[s]:
                continue
            tok = int(nxt[s])
            self.out[int(self.uid[s])].append(tok)
            self.pos[s] += 1
            self.remaining[s] -= 1
            if self.remaining[s] <= 0 or self.pos[s] >= self.max_seq - 1:
                done.append(Completion(int(self.uid[s]),
                                       self.out.pop(int(self.uid[s]))))
                self.active[s] = False
                self.uid[s] = -1
        self.tokens = jnp.asarray(nxt[:, None], jnp.int32)
        return done


def _pad_cache_seq(cache_small: list, cache_big: list) -> list:
    """Zero-pad a prefill cache (seq = prompt len) to the slot cache shape."""
    out = []
    for small, big in zip(cache_small, cache_big):
        slot = {}
        for k, v in small.items():
            tgt = big[k].shape[1:]
            pads = [(0, t - s) for s, t in zip(v.shape[1:], tgt)]
            slot[k] = jnp.pad(v, [(0, 0)] + pads)
        out.append(slot)
    return out


class ServingEngine:
    """Front door: WS-scheduled admission over a fleet of replicas, with
    replica failover, bounded requeues and explicit drain accounting."""

    def __init__(self, replicas: list, *, policy: str | Policy = "ws",
                 speed_fn=None,
                 heartbeat: HeartbeatMonitor | None = None,
                 heartbeat_ticks: int | None = None,
                 max_requeues: int = 2,
                 default_deadline_ticks: int | None = None,
                 tracer: obs_trace.Tracer | None = None,
                 metrics: obs_metrics.Registry | None = None):
        self.replicas = replicas
        self.policy = policy if isinstance(policy, Policy) \
            else make_policy(policy, speed_fn=speed_fn)
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        reg = metrics if metrics is not None else obs_metrics.REGISTRY
        self._m_submitted = reg.counter(
            "engine_requests_total", "requests submitted")
        self._m_completed = reg.counter(
            "engine_completions_total", "requests completed")
        self._m_failed = reg.counter(
            "engine_failures_total", "terminal failures, by reason")
        self._m_evictions = reg.counter(
            "engine_evictions_total", "replicas evicted")
        self._m_requeues = reg.counter(
            "engine_requeues_total", "requests re-admitted after a fault")
        self._m_queue_wait = reg.histogram(
            "engine_queue_wait_ticks", "ticks from submit to first admit")
        self._m_latency = reg.histogram(
            "engine_request_ticks", "ticks from submit to terminal record")
        self.heartbeat = heartbeat
        if self.heartbeat is None and heartbeat_ticks is not None:
            self.heartbeat = HeartbeatMonitor(timeout=heartbeat_ticks)
        self.max_requeues = max_requeues
        self.default_deadline_ticks = default_deadline_ticks
        self.healthy = [True] * len(replicas)
        self.backlog: deque[Request] = deque()
        self.completed: list[Completion] = []
        self.failed: list[RequestFailure] = []
        self._inflight: dict[int, tuple[Request, int]] = {}   # uid -> (req, i)
        self._requeues: dict[int, int] = {}
        self._submit_tick: dict[int, int] = {}
        self._admit_tick: dict[int, int] = {}
        self._tick = 0

    # ------------------------------------------------------------ admission
    def submit(self, req: Request) -> None:
        self._submit_tick.setdefault(req.uid, self._tick)
        self._m_submitted.inc()
        self.tracer.begin("request", id=req.uid, weight=req.weight)
        self.backlog.append(req)

    def _admit_backlog(self) -> None:
        # The policy always sees the *full* replica list: evicted replicas
        # are masked as zero-capacity views, so a stateful policy's pointer
        # (DRR._next) keeps addressing physical replicas across failover.
        # With a forced-capacity policy (OD), "queued" means newly admitted
        # this call — at most forced_capacity fresh requests per replica per
        # tick, and never more than the replica's free slots.
        forced = getattr(self.policy, "forced_capacity", None)
        newly = [0] * len(self.replicas)
        while self.backlog:
            if not any(self.healthy):
                return
            views = []
            for i, rep in enumerate(self.replicas):
                if not self.healthy[i]:
                    views.append(QueueState(tasks=0, weight=0.0, cap=0))
                    continue
                used, qw = rep.queue_len(), rep.queued_weight()
                if forced is not None:
                    views.append(QueueState(
                        tasks=newly[i], weight=qw,
                        cap=min(forced, rep.capacity() - used)))
                else:
                    views.append(QueueState(tasks=used, weight=qw,
                                            cap=rep.capacity()))
            i = self.policy.pick(self.backlog[0].weight, views)
            if i is None:
                return                       # every healthy replica full
            req = self.backlog.popleft()
            try:
                self.replicas[i].admit(req)
            except RuntimeError as e:
                # Scheduler race: the policy saw a free slot that is gone.
                # Requeue instead of crashing the engine loop.
                if not self._requeue(req, f"admit: {e!r}"):
                    continue
                self.backlog.appendleft(req)
                return
            except Exception as e:
                self._evict(i, f"admit raised: {e!r}")
                self.backlog.appendleft(req)
                continue
            newly[i] += 1
            self._inflight[req.uid] = (req, i)
            if req.uid not in self._admit_tick:
                self._admit_tick[req.uid] = self._tick
                self._m_queue_wait.observe(
                    self._tick - self._submit_tick[req.uid])
            self.tracer.instant("request.admit", uid=req.uid, replica=i)

    def _fail(self, failure: RequestFailure) -> None:
        """Record one terminal failure (the only way ``failed`` grows)."""
        self.failed.append(failure)
        self._m_failed.inc(reason=failure.reason)
        self._m_latency.observe(
            self._tick - self._submit_tick.get(failure.uid, self._tick))
        self.tracer.end("request", id=failure.uid, outcome=failure.reason)

    def _requeue(self, req: Request, detail: str) -> bool:
        """Charge one requeue; False = budget exhausted (request failed)."""
        n = self._requeues.get(req.uid, 0)
        if n >= self.max_requeues:
            self._fail(RequestFailure(req.uid, "requeue_exhausted", detail))
            return False
        self._requeues[req.uid] = n + 1
        self._m_requeues.inc()
        self.tracer.instant("request.requeue", uid=req.uid, detail=detail)
        return True

    # ------------------------------------------------------------- failover
    def _evict(self, i: int, detail: str) -> None:
        """Remove replica i from service; re-admit its in-flight requests."""
        if not self.healthy[i]:
            return
        self.healthy[i] = False
        self._m_evictions.inc()
        self.tracer.instant("replica.evict", replica=i, detail=detail)
        rep = self.replicas[i]
        try:
            uids = rep.active_uids()
        except Exception:
            uids = [u for u, (_, j) in self._inflight.items() if j == i]
        for uid in uids:
            ent = self._inflight.pop(uid, None)
            if ent is None:
                continue
            req, _ = ent
            if self._requeue(req, f"replica {i} evicted: {detail}"):
                self.backlog.appendleft(req)

    def _expire_deadlines(self) -> None:
        for uid, (req, i) in list(self._inflight.items()):
            ddl = req.deadline_ticks or self.default_deadline_ticks
            if ddl is None or self._tick - self._submit_tick[uid] < ddl:
                continue
            del self._inflight[uid]
            partial: list[int] = []
            if self.healthy[i]:
                try:
                    partial = self.replicas[i].release(uid)
                except Exception:
                    pass
            self._fail(RequestFailure(
                uid, "timeout", f"deadline {ddl} ticks exceeded", partial))
        for req in [r for r in self.backlog]:
            ddl = req.deadline_ticks or self.default_deadline_ticks
            if ddl is not None and self._tick - self._submit_tick[req.uid] >= ddl:
                self.backlog.remove(req)
                self._fail(RequestFailure(
                    req.uid, "timeout", f"deadline {ddl} ticks exceeded "
                    "while queued"))

    def _fail_remaining(self, reason: str, detail: str) -> None:
        for uid, (req, i) in list(self._inflight.items()):
            partial = []
            if self.healthy[i]:
                try:
                    partial = self.replicas[i].release(uid)
                except Exception:
                    pass
            self._fail(RequestFailure(uid, reason, detail, partial))
        self._inflight.clear()
        while self.backlog:
            req = self.backlog.popleft()
            self._fail(RequestFailure(req.uid, reason, detail))

    # ------------------------------------------------------------- main loop
    def run_until_drained(self, *, max_ticks: int = 10_000
                          ) -> list[Completion]:
        """Tick until every submitted request has a terminal record.

        Returns the completions (as before); explicit failure/timeout
        records accumulate in ``self.failed`` — nothing is dropped silently,
        including at ``max_ticks``.
        """
        for _ in range(max_ticks):
            self._tick += 1
            with self.tracer.span("engine.tick", tick=self._tick):
                if self.heartbeat is not None:
                    for h in self.heartbeat.failed(now=self._tick):
                        if h.startswith("replica"):
                            i = int(h[len("replica"):])
                            if 0 <= i < len(self.replicas) \
                                    and self.healthy[i]:
                                self._evict(i, "heartbeat timeout")
                with self.tracer.span("engine.admit"):
                    self._admit_backlog()
                busy = False
                for i, rep in enumerate(self.replicas):
                    if not self.healthy[i]:
                        continue
                    try:
                        with self.tracer.span(f"replica{i}.tick"):
                            done = rep.tick()
                    except Exception as e:
                        self._evict(i, f"tick raised: {e!r}")
                        continue
                    if self.heartbeat is not None:
                        self.heartbeat.beat(f"replica{i}", now=self._tick)
                    for c in done:
                        self._inflight.pop(c.uid, None)
                        self.completed.append(c)
                        self._m_completed.inc()
                        self._m_latency.observe(
                            self._tick - self._submit_tick[c.uid])
                        self.tracer.end("request", id=c.uid, outcome="ok")
                    busy |= rep.queue_len() > 0
                    self.tracer.counter(f"replica{i}.queued_weight",
                                        weight=rep.queued_weight())
                self._expire_deadlines()
            if not any(self.healthy) and (self.backlog or self._inflight):
                self._fail_remaining("no_replicas",
                                     "all replicas evicted")
                break
            if not busy and not self.backlog and not self._inflight:
                break
        else:
            self._fail_remaining(
                "max_ticks", f"undrained after {max_ticks} ticks")
        return self.completed

    def stats(self) -> dict[str, Any]:
        """Serving-side failure breakdown (mirrors ``Farm.stats()``)."""
        reasons: dict[str, int] = {}
        for f in self.failed:
            reasons[f.reason] = reasons.get(f.reason, 0) + 1
        return dict(
            ticks=self._tick,
            completed=len(self.completed),
            failed=len(self.failed),
            failed_by_reason=reasons,
            requeues=sum(self._requeues.values()),
            evicted_replicas=[i for i, h in enumerate(self.healthy) if not h],
            healthy_replicas=sum(self.healthy),
        )
