"""Slot-batched serving engine with the paper's WS request scheduling.

The paper's farm is applied here as a *runtime feature* (DESIGN.md §5): a
fleet of model replicas is a farm; requests are tasks whose weight is the
prompt length (the serving analogue of weight = r cases at a node); the
emitter assigns each request to the replica with the least outstanding
weighted work — FastFlow's ``ws_scheduler`` verbatim, from
:mod:`repro.core.scheduler`.

Each replica runs **continuous batching** over a fixed number of cache
slots: one jitted ``decode_step`` advances every active slot per tick;
prompts are prefilled into free slots (batch-1 prefill merged into the slot
axis); finished sequences free their slot immediately.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import Policy, QueueState, make_policy
from repro.models.model import Model
from repro.serve.sampling import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0

    @property
    def weight(self) -> float:
        return float(len(self.prompt) + self.max_new_tokens)


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: list[int]


class Replica:
    """One model replica: fixed slot batch + shared cache."""

    def __init__(self, model: Model, params: Any, *, n_slots: int,
                 max_seq: int, seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = model.init_cache(n_slots, max_seq)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.pos = np.zeros(n_slots, np.int64)            # next write index
        self.remaining = np.zeros(n_slots, np.int64)
        self.active = np.zeros(n_slots, bool)
        self.uid = np.full(n_slots, -1, np.int64)
        self.out: dict[int, list[int]] = {}
        self.key = jax.random.key(seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, t, max_seq=max_seq))

    # -- WorkerView for the WS policy ---------------------------------------
    def queue_len(self) -> int:
        return int(self.active.sum())

    def queued_weight(self) -> float:
        return float(self.remaining[self.active].sum())

    def capacity(self) -> int:
        return self.n_slots

    # -- admission -----------------------------------------------------------
    def admit(self, req: Request) -> None:
        free = np.flatnonzero(~self.active)
        if not free.size:
            raise RuntimeError("no free slot (scheduler bug)")
        s = int(free[0])
        logits, cache1 = self._prefill(self.params,
                                       jnp.asarray(req.prompt)[None])
        # splice the batch-1 prefill cache into slot s of the shared cache
        self.cache = jax.tree.map(
            lambda big, one: big.at[s:s + 1].set(one.astype(big.dtype)),
            self.cache, _pad_cache_seq(cache1, self.cache))
        tok = int(jnp.argmax(logits, -1)[0])
        self.tokens = self.tokens.at[s, 0].set(tok)
        self.pos[s] = len(req.prompt)
        self.remaining[s] = req.max_new_tokens - 1
        self.active[s] = True
        self.uid[s] = req.uid
        self.out[req.uid] = [tok]

    # -- one decode tick over all active slots -------------------------------
    def tick(self) -> list[Completion]:
        if not self.active.any():
            return []
        # Per-slot positions: every active slot advances at its own index
        # (continuous batching); the decode step masks per row.
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, pos_vec)
        self.key, sub = jax.random.split(self.key)
        nxt = np.asarray(sample(logits, sub))
        done: list[Completion] = []
        for s in range(self.n_slots):
            if not self.active[s]:
                continue
            tok = int(nxt[s])
            self.out[int(self.uid[s])].append(tok)
            self.pos[s] += 1
            self.remaining[s] -= 1
            if self.remaining[s] <= 0 or self.pos[s] >= self.max_seq - 1:
                done.append(Completion(int(self.uid[s]),
                                       self.out.pop(int(self.uid[s]))))
                self.active[s] = False
                self.uid[s] = -1
        self.tokens = jnp.asarray(nxt[:, None], jnp.int32)
        return done


def _pad_cache_seq(cache_small: list, cache_big: list) -> list:
    """Zero-pad a prefill cache (seq = prompt len) to the slot cache shape."""
    out = []
    for small, big in zip(cache_small, cache_big):
        slot = {}
        for k, v in small.items():
            tgt = big[k].shape[1:]
            pads = [(0, t - s) for s, t in zip(v.shape[1:], tgt)]
            slot[k] = jnp.pad(v, [(0, 0)] + pads)
        out.append(slot)
    return out


class ServingEngine:
    """Front door: WS-scheduled admission over a fleet of replicas."""

    def __init__(self, replicas: list[Replica], *,
                 policy: str | Policy = "ws"):
        self.replicas = replicas
        self.policy = policy if isinstance(policy, Policy) \
            else make_policy(policy)
        self.backlog: deque[Request] = deque()
        self.completed: list[Completion] = []

    def submit(self, req: Request) -> None:
        self.backlog.append(req)

    def _admit_backlog(self) -> None:
        while self.backlog:
            views = [QueueState(tasks=r.queue_len(),
                                weight=r.queued_weight(),
                                cap=r.capacity()) for r in self.replicas]
            i = self.policy.pick(self.backlog[0].weight, views)
            if i is None:
                return                       # every replica full
            self.replicas[i].admit(self.backlog.popleft())

    def run_until_drained(self, *, max_ticks: int = 10_000
                          ) -> list[Completion]:
        for _ in range(max_ticks):
            self._admit_backlog()
            busy = False
            for r in self.replicas:
                done = r.tick()
                self.completed.extend(done)
                busy |= r.queue_len() > 0
            if not busy and not self.backlog:
                break
        return self.completed
