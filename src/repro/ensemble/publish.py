"""Bridge trained forests into the serving stack (pack -> atomic publish).

The training loop's last mile: pack the ordered trees with
:func:`repro.infer.forest.Forest.pack` and publish them atomically through
:func:`repro.infer.registry.publish`, stamping the manifest with everything
needed to reproduce or audit the model (seed, mtry, bootstrap, grow
criterion, OOB score).  From there the standard serving flow applies
unchanged — ``ModelHandle`` pins the version, ``set_canary`` routes a uid
fraction onto a candidate, ``promote_canary`` / ``rollback`` move the fleet
(see ``examples/train_forest.py`` for the full
train -> publish -> canary -> promote loop).
"""

from __future__ import annotations

from typing import Any

from repro.core.binning import BinnedDataset
from repro.ensemble import oob as oob_mod
from repro.ensemble.trainer import ForestConfig, TrainResult
from repro.infer import registry


def forest_metadata(fc: ForestConfig, *, n_attrs: int,
                    oob: oob_mod.OOBResult | None = None,
                    extra: dict | None = None) -> dict[str, Any]:
    """The manifest metadata block for a published forest."""
    meta: dict[str, Any] = {
        "kind": "random_forest",
        "seed": fc.seed,
        "n_trees": fc.n_trees,
        "mtry": fc.resolved_mtry(n_attrs),
        "bootstrap": fc.bootstrap,
        "criterion": fc.grow.criterion,
        "min_objs": fc.grow.min_objs,
        "max_depth": fc.grow.max_depth,
    }
    if oob is not None:
        meta["oob_score"] = oob.score
        meta["oob_coverage"] = oob.coverage
    if extra:
        meta.update(extra)
    return meta


def publish_forest(root: str, name: str, result: TrainResult,
                   ds: BinnedDataset, *, score_oob: bool = True,
                   weights=None, metadata: dict | None = None,
                   keep_last: int | None = None) -> str:
    """Pack + atomically publish a training run; returns the version path.

    ``score_oob=True`` (default, bootstrap runs only) computes the OOB
    estimate and records it in the manifest — the number a canary /
    promotion decision reads back via ``registry.manifest_of``.
    ``keep_last`` forwards to the registry's retention GC.
    """
    from repro.infer.forest import Forest
    oob = None
    if score_oob and result.config.bootstrap:
        oob = oob_mod.oob_score(result.trees, ds, result.config,
                                tree_ids=result.tree_ids)
    meta = forest_metadata(result.config, n_attrs=ds.n_attrs, oob=oob,
                           extra=metadata)
    meta["tree_ids"] = result.tree_ids
    meta["quarantined"] = result.quarantined
    forest = Forest.pack(result.trees, weights=weights)
    return registry.publish(root, name, forest, metadata=meta,
                            keep_last=keep_last)
