"""Deterministic per-tree bagging inputs: bootstrap weights + feature subsets.

Every randomised ingredient of a forest member is a *pure function* of
``(seed, tree_id)`` — the same content-addressed determinism discipline as
:mod:`repro.data.loader` (batch ``i`` is a pure function of ``(seed, step)``).
Nothing is sampled at dispatch time and no sampling state lives in the
trainer, so:

  * any farm worker can regenerate any tree's inputs after a crash — a
    retried tree task is bit-identical to its first attempt;
  * the forest does not depend on worker count, scheduling order or injected
    chaos: ``train_forest(n_workers=4, injector=...)`` equals the sequential
    per-tree oracle exactly;
  * the out-of-bag complement (:mod:`repro.ensemble.oob`) is recomputable
    anywhere from the same ``(seed, tree_id)`` key.

The bootstrap is expressed as *per-case weights* (draw counts times the
dataset's base weights) and the feature subset as a boolean *attribute
mask*, matching the ``case_w`` / ``attr_mask`` hooks on the growth engines
(:func:`repro.core.c45.build`, :func:`repro.core.frontier.build`) — per-tree
inputs never copy the dataset.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

#: Stream tags keeping the per-purpose PRNG streams disjoint for one seed.
TAG_BOOTSTRAP = 1
TAG_FEATURES = 2
TAG_PERMUTE = 3


def _rng(seed: int, tag: int, *key: int) -> np.random.Generator:
    """Content-addressed generator for one (seed, purpose, key) cell."""
    return np.random.default_rng((int(seed), int(tag), *map(int, key)))


def default_mtry(n_attrs: int) -> int:
    """Breiman's default feature-subset size: ceil(sqrt(A)), at least 1."""
    return max(1, int(math.ceil(math.sqrt(max(n_attrs, 0)))))


def bootstrap_counts(seed: int, tree_id: int, n_cases: int) -> np.ndarray:
    """(N,) int64 draw counts of the n-out-of-n bootstrap for one tree."""
    idx = _rng(seed, TAG_BOOTSTRAP, tree_id).integers(0, n_cases,
                                                      size=n_cases)
    return np.bincount(idx, minlength=n_cases).astype(np.int64)


def feature_mask(seed: int, tree_id: int, n_attrs: int,
                 mtry: int | None = None) -> np.ndarray:
    """(A,) bool mask with exactly ``mtry`` active attributes."""
    if mtry is None:
        mtry = default_mtry(n_attrs)
    if not 1 <= mtry <= n_attrs:
        raise ValueError(f"mtry={mtry} out of range [1, {n_attrs}]")
    mask = np.zeros((n_attrs,), dtype=bool)
    chosen = _rng(seed, TAG_FEATURES, tree_id).choice(n_attrs, size=mtry,
                                                      replace=False)
    mask[chosen] = True
    return mask


def permutation(seed: int, attr: int, repeat: int, n_cases: int) -> np.ndarray:
    """(N,) deterministic permutation for OOB variable importance."""
    return _rng(seed, TAG_PERMUTE, attr, repeat).permutation(n_cases)


@dataclasses.dataclass(frozen=True)
class TreeSample:
    """Everything tree ``tree_id`` needs beyond the shared dataset."""

    tree_id: int
    counts: np.ndarray      # int64 (N,) bootstrap draw counts (ones if off)
    case_w: np.ndarray      # f32 (N,) counts * base weights -> engine hook
    attr_mask: np.ndarray   # bool (A,) feature subset -> engine hook

    @property
    def oob(self) -> np.ndarray:
        """(N,) bool: cases *not* drawn by this tree's bootstrap."""
        return self.counts == 0


def draw(seed: int, tree_id: int, *, n_cases: int, n_attrs: int,
         base_w: np.ndarray | None = None, mtry: int | None = None,
         bootstrap: bool = True) -> TreeSample:
    """The per-tree sample: pure in ``(seed, tree_id)`` given the shapes."""
    counts = (bootstrap_counts(seed, tree_id, n_cases) if bootstrap
              else np.ones((n_cases,), np.int64))
    w = counts.astype(np.float32)
    if base_w is not None:
        w = w * np.asarray(base_w, np.float32)
    return TreeSample(tree_id=int(tree_id), counts=counts, case_w=w,
                      attr_mask=feature_mask(seed, tree_id, n_attrs, mtry))
