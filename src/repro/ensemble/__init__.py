"""Ensemble training subsystem: farm-parallel random forests.

Four layers (README "Ensemble training"):

  * :mod:`repro.ensemble.sampling` — per-tree bootstrap weights and feature
    subsets as pure functions of ``(seed, tree_id)``, so any worker can
    regenerate any tree's inputs after a crash;
  * :mod:`repro.ensemble.trainer`  — tree-level dispatch over the supervised
    farm (one task per tree; retry / quarantine / worker-death semantics
    inherited) or the jitted frontier superstep, both bit-identical to the
    sequential per-tree oracle;
  * :mod:`repro.ensemble.oob`      — out-of-bag error and permutation
    variable importance from the bootstrap complements;
  * :mod:`repro.ensemble.publish`  — pack the forest and atomically publish
    it into the serving registry (:mod:`repro.infer`).
"""

from repro.ensemble.oob import (                                  # noqa: F401
    OOBResult, oob_score, permutation_importance)
from repro.ensemble.publish import publish_forest                 # noqa: F401
from repro.ensemble.trainer import (                              # noqa: F401
    ForestConfig, QuarantinedTrees, TrainResult, train_forest,
    train_forest_sequential, train_tree)
