"""Farm-parallel random-forest training: one farm task per tree.

The paper parallelises *within* one C4.5 build (nodes/attributes streams);
an ensemble adds the natural outer level — whole trees as independent tasks,
the across-tree axis the Bayesian-trees line of related work targets
(arXiv:2207.12688, arXiv:2301.09090).  This trainer dispatches T tree tasks
over the supervised :class:`repro.core.farm.Farm`:

  * a **tree task** is pure: the worker regenerates its bootstrap weights
    and feature subset from ``(seed, tree_id)`` (:mod:`.sampling`) and grows
    the tree with the shared dataset — so the farm's retry / quarantine /
    worker-death re-dispatch semantics are inherited unchanged, and a chaos
    run produces the exact same forest as the sequential per-tree oracle
    (:func:`train_forest_sequential`);
  * trees are collected by ``tree_id``, so completion order (and hence
    worker count, scheduling, injected faults) cannot reorder the forest;
  * ``impl="c45"`` grows each tree with the sequential oracle engine;
    ``impl="frontier"`` grows it through the jitted superstep
    (:func:`repro.core.frontier.build`), with the per-tree feature mask and
    bootstrap weights threaded into the split search as traced arguments —
    every tree reuses one compiled build.

A tree that exhausts its retry budget is quarantined; ``strict=True``
(default) raises :class:`QuarantinedTrees`, otherwise the forest is returned
without it (recorded in ``TrainResult.quarantined``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core import c45, frontier
from repro.core.binning import BinnedDataset
from repro.core.config import GrowConfig
from repro.core.farm import Farm, FaultPolicy, TaskFailure
from repro.core.scheduler import Policy
from repro.core.tree import Tree
from repro.ensemble import sampling
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

IMPLS = ("c45", "frontier")


@dataclasses.dataclass(frozen=True)
class ForestConfig:
    """Ensemble-level knobs; ``grow`` is the shared per-tree GrowConfig.

    ``mtry=None`` uses :func:`repro.ensemble.sampling.default_mtry`
    (ceil(sqrt(A))); ``bootstrap=False`` disables resampling (every tree
    sees the full weights — pure feature-subspace bagging, no OOB).
    """

    n_trees: int = 8
    seed: int = 0
    mtry: int | None = None
    bootstrap: bool = True
    grow: GrowConfig = dataclasses.field(default_factory=GrowConfig)

    def resolved_mtry(self, n_attrs: int) -> int:
        return self.mtry if self.mtry is not None \
            else sampling.default_mtry(n_attrs)

    def sample(self, ds: BinnedDataset, tree_id: int) -> sampling.TreeSample:
        return sampling.draw(self.seed, tree_id, n_cases=ds.n_cases,
                             n_attrs=ds.n_attrs, base_w=ds.w, mtry=self.mtry,
                             bootstrap=self.bootstrap)


class QuarantinedTrees(RuntimeError):
    """Raised under ``strict=True`` when tree tasks exhausted their retries."""

    def __init__(self, failures: list[TaskFailure]):
        self.failures = failures
        ids = [f.payload for f in failures]
        super().__init__(f"{len(failures)} tree task(s) quarantined: {ids}")


@dataclasses.dataclass
class TrainResult:
    """Ordered forest + execution breakdown of one training run."""

    trees: list[Tree]           # ascending tree_id, quarantined ids omitted
    tree_ids: list[int]
    config: ForestConfig
    stats: dict[str, Any]       # Farm.stats() + wall_s / trees_per_s
    quarantined: list[int]

    @property
    def n_trees(self) -> int:
        return len(self.trees)


def train_tree(ds: BinnedDataset, fc: ForestConfig, tree_id: int, *,
               impl: str = "c45", kernel_impl: str = "jnp") -> Tree:
    """Grow forest member ``tree_id``: a pure function of (ds, fc, tree_id).

    Shared verbatim by the farm workers and the sequential oracle, so both
    paths make bitwise identical trees for a given ``(seed, tree_id)``.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r} (one of {IMPLS})")
    s = fc.sample(ds, tree_id)
    if impl == "c45":
        return c45.build(ds, fc.grow, attr_mask=s.attr_mask,
                         case_w=s.case_w)
    return frontier.build(ds, fc.grow, impl=kernel_impl,
                          attr_mask=s.attr_mask, case_w=s.case_w)


def train_forest_sequential(ds: BinnedDataset, fc: ForestConfig, *,
                            impl: str = "c45", kernel_impl: str = "jnp"
                            ) -> list[Tree]:
    """The per-tree oracle every farm run must reproduce bit-for-bit."""
    return [train_tree(ds, fc, t, impl=impl, kernel_impl=kernel_impl)
            for t in range(fc.n_trees)]


def train_forest(ds: BinnedDataset, fc: ForestConfig, *,
                 impl: str = "c45", kernel_impl: str = "jnp",
                 n_workers: int = 4, policy: Policy | None = None,
                 fault: FaultPolicy | None = None, injector: Any = None,
                 strict: bool = True, stats_out: dict | None = None,
                 tracer: obs_trace.Tracer | None = None,
                 metrics: obs_metrics.Registry | None = None) -> TrainResult:
    """Train the forest through the supervised farm; oracle-equal result.

    One farm task per tree (weight = N cases, the WS weight of a full
    build); the worker service is pure, so the farm may retry / re-dispatch
    tree tasks on crashes, hangs and worker deaths without changing the
    forest.  ``injector`` wraps the tree service with
    :class:`repro.core.faults.FaultInjector` for chaos runs.
    """
    tracer = tracer if tracer is not None else obs_trace.NULL
    reg = metrics if metrics is not None else obs_metrics.REGISTRY
    m_trees = reg.counter("ensemble_trees_trained_total",
                          "forest members grown, by impl= label")
    m_tree_s = reg.histogram("ensemble_tree_seconds",
                             "wall time per tree task attempt")
    m_rate = reg.gauge("ensemble_trees_per_s",
                       "trees/sec of the last train_forest run")

    done: dict[int, Tree] = {}
    quarantined: list[TaskFailure] = []

    def emitter(task: Any, send) -> None:
        if task is None:                     # start-up: the whole forest
            for tid in range(fc.n_trees):
                send(tid, weight=float(max(ds.n_cases, 1)))
            return
        if isinstance(task, TaskFailure):    # tree exhausted its retries
            quarantined.append(task)
            return
        tid, tree = task
        done[tid] = tree

    def worker(tid: int):
        t0 = time.perf_counter()
        with tracer.span("ensemble.tree", tree=tid, impl=impl):
            tree = train_tree(ds, fc, tid, impl=impl,
                              kernel_impl=kernel_impl)
        m_tree_s.observe(time.perf_counter() - t0)
        m_trees.inc(impl=impl)
        return tid, tree

    farm = Farm(n_workers, policy=policy, fault=fault, tracer=tracer,
                metrics=reg)
    svc = injector.wrap_worker(worker) if injector is not None else worker
    t0 = time.perf_counter()
    stats = dict(farm.run(emitter, svc))
    wall = time.perf_counter() - t0
    stats["wall_s"] = wall
    stats["trees_per_s"] = len(done) / wall if wall > 0 else float("inf")
    m_rate.set(stats["trees_per_s"], impl=impl)
    if stats_out is not None:
        stats_out.update(stats)
    if strict and quarantined:
        raise QuarantinedTrees(quarantined)
    ids = sorted(done)
    return TrainResult(
        trees=[done[t] for t in ids], tree_ids=ids, config=fc, stats=stats,
        quarantined=sorted(int(f.payload) for f in quarantined))
