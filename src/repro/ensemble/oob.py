"""Out-of-bag evaluation: generalisation error and variable importance.

Each bootstrap leaves ~36.8% of cases out of its tree's sample; those cases
are an honest test set *for that tree*.  Aggregating, every case is scored
by the sub-ensemble of trees that never saw it — the OOB estimate of
generalisation error, free with training (Breiman 1996).  Because the
bootstrap complements are pure functions of ``(seed, tree_id)``
(:mod:`.sampling`), OOB needs no state from the training run: any process
holding the trees and the config can recompute it.

Predictions go through the packed-forest batched path
(:func:`repro.infer.forest.predict_per_tree`) — one ``(T, N)`` tensor, the
OOB mask applied to the vote tally — so OOB costs one batched inference
sweep, not T × N tree walks.

Permutation variable importance: re-score OOB accuracy with attribute
``a``'s column deterministically permuted; the accuracy drop is ``a``'s
importance.  Permutations are keyed by ``(seed, attr, repeat)``, so the
report is replayable too.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.binning import BinnedDataset
from repro.core.tree import Tree
from repro.ensemble import sampling
from repro.ensemble.trainer import ForestConfig
from repro.infer.forest import Forest, predict_per_tree
from repro.obs import metrics as obs_metrics


def oob_matrix(fc: ForestConfig, n_cases: int,
               tree_ids: list[int] | None = None) -> np.ndarray:
    """(T, N) bool: ``[t, i]`` = case i is out-of-bag for tree t."""
    ids = tree_ids if tree_ids is not None else list(range(fc.n_trees))
    return np.stack([
        sampling.bootstrap_counts(fc.seed, t, n_cases) == 0 for t in ids])


def _vote(per_tree: np.ndarray, oob: np.ndarray, n_classes: int
          ) -> np.ndarray:
    """(N,) OOB-masked majority vote; -1 where no tree holds the case out."""
    t_dim, n = per_tree.shape
    onehot = np.zeros((t_dim, n, n_classes), np.float32)
    np.put_along_axis(onehot, per_tree[:, :, None].astype(np.int64), 1.0,
                      axis=2)
    tally = np.einsum("tnc,tn->nc", onehot, oob.astype(np.float32))
    pred = np.argmax(tally, axis=-1).astype(np.int32)
    return np.where(oob.any(axis=0), pred, -1)


@dataclasses.dataclass(frozen=True)
class OOBResult:
    score: float            # accuracy over covered cases
    coverage: float         # fraction of cases with >= 1 OOB tree
    n_covered: int
    pred: np.ndarray        # (N,) int32 OOB prediction, -1 = uncovered


def oob_score(trees: list[Tree], ds: BinnedDataset, fc: ForestConfig, *,
              tree_ids: list[int] | None = None, impl: str = "vmap",
              metrics: obs_metrics.Registry | None = None) -> OOBResult:
    """OOB generalisation estimate of a trained forest.

    ``tree_ids`` names the ``(seed, tree_id)`` keys behind ``trees`` when
    they are not simply ``0..T-1`` (e.g. a non-strict chaos run that dropped
    a quarantined member).  Requires ``fc.bootstrap``; without resampling
    there is no out-of-bag complement.
    """
    if not fc.bootstrap:
        raise ValueError("OOB is undefined without bootstrap resampling")
    if not trees:
        raise ValueError("OOB needs at least one tree")
    forest = Forest.pack(trees)
    per_tree = np.asarray(
        predict_per_tree(forest, ds.x, ds.attr_is_cont, impl=impl))
    oob = oob_matrix(fc, ds.n_cases, tree_ids)
    if oob.shape[0] != len(trees):
        raise ValueError(f"{len(trees)} trees vs {oob.shape[0]} tree_ids")
    pred = _vote(per_tree, oob, ds.n_classes)
    covered = pred >= 0
    n_cov = int(covered.sum())
    score = float((pred[covered] == ds.y[covered]).mean()) if n_cov \
        else float("nan")
    reg = metrics if metrics is not None else obs_metrics.REGISTRY
    reg.gauge("ensemble_oob_score",
              "OOB accuracy of the last scored forest").set(score)
    reg.gauge("ensemble_oob_coverage",
              "fraction of cases with >= 1 OOB tree").set(
        n_cov / max(ds.n_cases, 1))
    return OOBResult(score=score, coverage=n_cov / max(ds.n_cases, 1),
                     n_covered=n_cov, pred=pred)


def permutation_importance(trees: list[Tree], ds: BinnedDataset,
                           fc: ForestConfig, *,
                           tree_ids: list[int] | None = None,
                           impl: str = "vmap", n_repeats: int = 1
                           ) -> np.ndarray:
    """(A,) mean OOB-accuracy drop when attribute ``a``'s column is permuted.

    Deterministic: permutation ``(a, r)`` is a pure function of
    ``(fc.seed, a, r)``.  Attributes the forest never splits on score ~0.
    """
    if n_repeats < 1:
        raise ValueError("n_repeats must be >= 1")
    base = oob_score(trees, ds, fc, tree_ids=tree_ids, impl=impl,
                     metrics=obs_metrics.Registry())
    forest = Forest.pack(trees)
    oob = oob_matrix(fc, ds.n_cases, tree_ids)
    x = np.asarray(ds.x)
    imp = np.zeros((ds.n_attrs,), np.float64)
    for a in range(ds.n_attrs):
        drops = []
        for r in range(n_repeats):
            xp = x.copy()
            perm = sampling.permutation(fc.seed, a, r, ds.n_cases)
            xp[:, a] = xp[perm, a]
            per_tree = np.asarray(
                predict_per_tree(forest, xp, ds.attr_is_cont, impl=impl))
            pred = _vote(per_tree, oob, ds.n_classes)
            covered = pred >= 0
            acc = float((pred[covered] == ds.y[covered]).mean()) \
                if covered.any() else float("nan")
            drops.append(base.score - acc)
        imp[a] = float(np.mean(drops))
    return imp
