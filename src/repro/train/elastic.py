"""Elastic scaling, failure detection and straggler mitigation (host plane).

At 1000+ nodes, three control-plane mechanisms keep a run alive:

  * :class:`HeartbeatMonitor` — hosts report liveness; a host silent for
    ``timeout`` seconds is declared failed.  The driver reacts by draining
    the step, checkpointing (or falling back to the last valid checkpoint),
    and replanning the mesh without the lost hosts.
  * :func:`plan_mesh` — given the surviving chip count, pick the largest
    coherent (pod, data, model) grid that preserves the TP anchor (model=16,
    the divisibility the whole fleet's layouts are built on) — elastic
    *data*-parallel width, fixed *model* width.
  * :class:`StragglerMonitor` — per-step durations; hosts slower than
    ``factor`` x the running median get flagged.  Host-side work (data
    shards, eval requests) is rebalanced through the paper's own WS policy
    (the YaDT-FF weighted scheduler — see core/scheduler.py), which is
    exactly a straggler-aware least-loaded assignment.

The SPMD step itself is gang-scheduled: failures surface as collective
timeouts; the driver loop in ``launch/train.py`` wires these pieces to
checkpoint/restore.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Sequence

from repro.core.scheduler import WS, HealthWS, QueueState
from repro.obs import metrics as obs_metrics

TP_ANCHOR = 16   # model-axis width the fleet's divisibility is built on


@dataclasses.dataclass
class HostState:
    last_seen: float
    step: int = -1


class HeartbeatMonitor:
    def __init__(self, timeout: float = 60.0,
                 metrics: obs_metrics.Registry | None = None):
        self.timeout = timeout
        self.hosts: dict[str, HostState] = {}
        reg = metrics if metrics is not None else obs_metrics.REGISTRY
        self._m_beats = reg.counter(
            "heartbeat_beats_total", "liveness reports, by host= label")
        self._m_alive = reg.gauge(
            "heartbeat_hosts_alive", "hosts within the liveness timeout")
        self._m_failed = reg.gauge(
            "heartbeat_hosts_failed", "hosts past the liveness timeout")

    def beat(self, host: str, step: int = -1,
             now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        self.hosts[host] = HostState(last_seen=now, step=step)
        self._m_beats.inc(host=host)

    def failed(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        bad = [h for h, s in self.hosts.items()
               if now - s.last_seen > self.timeout]
        self._m_failed.set(len(bad))
        self._m_alive.set(len(self.hosts) - len(bad))
        return bad

    def alive(self, now: float | None = None) -> list[str]:
        bad = set(self.failed(now))
        return [h for h in self.hosts if h not in bad]


def plan_mesh(n_chips: int, *, chips_per_pod: int = 256,
              model: int = TP_ANCHOR) -> tuple[tuple[int, ...],
                                               tuple[str, ...]]:
    """Largest usable (pod, data, model) grid for the surviving chips.

    Keeps model = TP_ANCHOR fixed (layout anchor), scales data width down to
    what the survivors support; multi-pod only when whole pods survive.
    """
    if n_chips < model:
        raise ValueError(f"need at least {model} chips, have {n_chips}")
    pods = n_chips // chips_per_pod
    if pods >= 2:
        usable_pods = pods
        data = chips_per_pod // model
        return (usable_pods, data, model), ("pod", "data", "model")
    data = n_chips // model
    return (data, model), ("data", "model")


def rebatch_for_mesh(global_batch: int, mesh_shape: Sequence[int],
                     axes: Sequence[str]) -> int:
    """Nearest feasible global batch for a replanned mesh (keeps per-replica
    batch constant: elastic batch scaling)."""
    dp = 1
    for n, a in zip(mesh_shape, axes):
        if a in ("pod", "data"):
            dp *= n
    per_replica = max(1, global_batch // dp)
    return per_replica * dp


class StragglerMonitor:
    """Flags hosts whose recent step times exceed factor x fleet median."""

    def __init__(self, factor: float = 1.5, window: int = 16):
        self.factor = factor
        self.times: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=window))

    def record(self, host: str, seconds: float) -> None:
        self.times[host].append(seconds)

    def _median(self, xs: list[float]) -> float:
        xs = sorted(xs)
        return xs[len(xs) // 2]

    def stragglers(self) -> list[str]:
        if len(self.times) < 2:
            return []
        med = self._median([self._median(list(v)) for v in self.times.values()
                            if v])
        return [h for h, v in self.times.items()
                if v and self._median(list(v)) > self.factor * med]

    def ws_weights(self) -> dict[str, float]:
        """Relative work weights for the WS scheduler: slow host -> less work.

        This plugs the paper's weighted scheduling into straggler mitigation:
        host-side tasks are dispatched with Farm(policy=WS()) where each
        host's queue weight is scaled by its observed slowdown.
        """
        if not self.times:
            return {}
        meds = {h: self._median(list(v)) for h, v in self.times.items() if v}
        fleet = self._median(list(meds.values()))
        return {h: fleet / m for h, m in meds.items()}


class FarmHealth:
    """Bridge the farm's execution events into the control plane.

    The supervised farm (:class:`repro.core.farm.Farm`) calls ``on_task``
    per completed attempt and ``on_worker_dead`` per lost worker; this class
    feeds those events into :class:`HeartbeatMonitor` (liveness) and
    :class:`StragglerMonitor` (per-worker speed), and closes the loop by
    producing the :class:`~repro.core.scheduler.HealthWS` policy that scales
    the paper's WS weights with observed worker health — straggler-aware,
    dead-worker-avoiding task placement.  Worker ``i`` is host ``"w{i}"`` in
    both monitors.
    """

    def __init__(self, n_workers: int, *,
                 heartbeat: HeartbeatMonitor | None = None,
                 straggler: StragglerMonitor | None = None):
        self.n_workers = n_workers
        self.heartbeat = heartbeat or HeartbeatMonitor()
        self.straggler = straggler or StragglerMonitor()
        self.dead: set[int] = set()

    @staticmethod
    def host(idx: int) -> str:
        return f"w{idx}"

    # -- farm-side hooks -----------------------------------------------------
    def on_task(self, idx: int, seconds: float,
                now: float | None = None) -> None:
        self.straggler.record(self.host(idx), seconds)
        self.heartbeat.beat(self.host(idx), now=now)

    def on_worker_dead(self, idx: int) -> None:
        self.dead.add(idx)

    # -- scheduler-side view -------------------------------------------------
    def speeds(self, now: float | None = None) -> dict[int, float]:
        """Per-worker speed factors; 0.0 = do not schedule (dead/silent)."""
        w = self.straggler.ws_weights()
        failed = set(self.heartbeat.failed(now))
        out: dict[int, float] = {}
        for i in range(self.n_workers):
            if i in self.dead or self.host(i) in failed:
                out[i] = 0.0
            else:
                out[i] = w.get(self.host(i), 1.0)
        return out

    def policy(self) -> HealthWS:
        return HealthWS(self.speeds)
