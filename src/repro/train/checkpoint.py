"""Fault-tolerant checkpointing: atomic, integrity-tagged, mesh-agnostic.

Design for 1000+ nodes (DESIGN.md §9):

  * **Mesh-agnostic layout** — leaves are written *unsharded* with their
    tree paths as keys, so a checkpoint saved on a (16,16) mesh restores
    onto a (2,16,16) or any elastic replan; re-sharding happens at
    ``device_put`` with the target shardings.  (On a real pod each host
    writes only its shard slice + a partition manifest; the gather-based
    writer here keeps the same on-disk contract.)
  * **Atomicity** — write to ``<dir>/tmp.<step>`` then ``os.replace``; a
    crash mid-write never corrupts the latest checkpoint.
  * **Integrity** — per-leaf CRC32 in ``manifest.json``; ``latest_valid``
    skips checkpoints that fail verification (torn writes on shared FS).
  * **Async** — ``save(..., blocking=False)`` snapshots to host memory
    synchronously (consistency point) and writes in a daemon thread, off
    the step critical path.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"
_SAVE_SEQ = itertools.count()

#: tmp.* directories older than this (seconds) are presumed abandoned by a
#: crashed writer and are garbage-collected by :func:`latest_valid`.
TMP_GC_AGE = 3600.0


class SaveHandle(str):
    """Path-like result of :func:`save`.

    Behaves as the checkpoint path string (back-compatible) and, for
    ``blocking=False`` saves, carries the writer thread: ``wait()`` joins it
    and **re-raises** any exception the writer hit — async write errors no
    longer vanish inside a daemon thread.
    """

    _thread: threading.Thread | None = None
    _box: dict | None = None

    @property
    def done(self) -> bool:
        return self._thread is None or not self._thread.is_alive()

    def wait(self, timeout: float | None = None) -> str:
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError(f"checkpoint writer for {self} still "
                                   f"running after {timeout}s")
        if self._box and self._box.get("exc") is not None:
            raise self._box["exc"]
        return str(self)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _to_storable(arr: np.ndarray) -> np.ndarray:
    """bf16 & friends are not npy-native: store raw bytes, dtype in manifest."""
    if arr.dtype.kind in "biufc?":
        return arr
    return np.ascontiguousarray(arr).view(np.uint8)


def _from_storable(arr: np.ndarray, dtype: str, shape) -> np.ndarray:
    target = np.dtype(jnp.dtype(dtype))
    if arr.dtype == target:
        return arr.reshape(shape)
    return arr.view(target).reshape(shape)


def save(directory: str, step: int, tree: Any, *, extra: dict | None = None,
         blocking: bool = True) -> SaveHandle:
    """Write checkpoint ``<directory>/step_<step>``.

    Returns a :class:`SaveHandle` (a ``str`` of the final path).  With
    ``blocking=False`` the write happens on a daemon thread; call
    ``handle.wait()`` before relying on the checkpoint — it re-raises any
    writer exception instead of losing it.
    """
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)   # synchronous snapshot = consistency point
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = os.path.join(directory,
                       f"tmp.{step}.{os.getpid()}.{next(_SAVE_SEQ)}")

    def write():
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for key, arr in flat.items():
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), _to_storable(arr))
            manifest["leaves"][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            import shutil
            shutil.rmtree(final)
        os.replace(tmp, final)

    handle = SaveHandle(final)
    if blocking:
        write()
        return handle
    box: dict = {"exc": None}

    def run():
        try:
            write()
        except BaseException as e:      # surfaced by SaveHandle.wait()
            box["exc"] = e

    t = threading.Thread(target=run, daemon=True)
    handle._thread, handle._box = t, box
    t.start()
    return handle


def verify(path: str) -> bool:
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        for key, meta in manifest["leaves"].items():
            arr = _from_storable(np.load(os.path.join(path, meta["file"])),
                                 meta["dtype"], meta["shape"])
            if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
                return False
        return True
    except (OSError, ValueError, KeyError, TypeError):
        return False


def gc_stale_tmp(directory: str, *, max_age: float = TMP_GC_AGE) -> list[str]:
    """Delete ``tmp.*`` directories older than ``max_age`` seconds.

    Crashed async writers leave these behind (the atomic ``os.replace``
    never ran); anything older than ``max_age`` cannot belong to a live
    writer and is reclaimed.  Returns the removed paths.
    """
    import shutil
    import time
    removed = []
    now = time.time()
    for d in os.listdir(directory):
        if not d.startswith("tmp."):
            continue
        path = os.path.join(directory, d)
        try:
            if now - os.path.getmtime(path) >= max_age:
                shutil.rmtree(path, ignore_errors=True)
                removed.append(path)
        except OSError:
            continue
    return removed


def latest_valid(directory: str, *,
                 gc_tmp_age: float | None = TMP_GC_AGE) -> str | None:
    if not os.path.isdir(directory):
        return None
    if gc_tmp_age is not None:
        gc_stale_tmp(directory, max_age=gc_tmp_age)
    steps = sorted((d for d in os.listdir(directory)
                    if d.startswith("step_")), reverse=True)
    for d in steps:
        path = os.path.join(directory, d)
        if verify(path):
            return path
    return None


def restore(path: str, like: Any, *, shardings: Any | None = None) -> Any:
    """Rebuild the pytree of ``like`` (a template/state) from ``path``.

    ``shardings``: optional matching pytree of NamedShardings — this is the
    elastic-rescale hook: restore onto any mesh regardless of the mesh the
    checkpoint was written from.
    """
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths_and_leaves))
    out = []
    for (path_elems, leaf), sh in zip(paths_and_leaves, shard_leaves):
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in path_elems)
        meta = manifest["leaves"][key]
        arr = _from_storable(np.load(os.path.join(path, meta["file"])),
                             meta["dtype"], meta["shape"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def manifest_step(path: str) -> int:
    with open(os.path.join(path, _MANIFEST)) as f:
        return int(json.load(f)["step"])
