"""AdamW from scratch (no optax in this environment, and none needed).

Moments are kept in float32 regardless of param dtype (bf16 params get a
f32 update then cast back — the moment tensors double as the "master"
precision).  Global-norm clipping and a warmup+cosine schedule included.
Moment pytrees inherit the params' sharding via out_shardings at jit time.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.lr * (cfg.min_lr_ratio
                    + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_moments(params: Any) -> tuple[Any, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, m: Any, v: Any, params: Any, step: jnp.ndarray,
    cfg: AdamWConfig,
) -> tuple[Any, Any, Any, dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (params', m', v', stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    count = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** count
    bc2 = 1.0 - cfg.b2 ** count

    def upd(g, m_, v_, p):
        g = g.astype(jnp.float32) * scale
        m_n = cfg.b1 * m_ + (1 - cfg.b1) * g
        v_n = cfg.b2 * v_ + (1 - cfg.b2) * jnp.square(g)
        update = (m_n / bc1) / (jnp.sqrt(v_n / bc2) + cfg.eps)
        p_f = p.astype(jnp.float32)
        p_n = p_f - lr * (update + cfg.weight_decay * p_f)
        return p_n.astype(p.dtype), m_n, v_n

    # flatten once (param trees contain structural tuples, so a tree.map
    # returning tuples would be ambiguous)
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(m)
    v_leaves = treedef.flatten_up_to(v)
    new = [upd(g, m_, v_, p) for g, m_, v_, p
           in zip(g_leaves, m_leaves, v_leaves, p_leaves)]
    params_n = jax.tree.unflatten(treedef, [t[0] for t in new])
    m_n = jax.tree.unflatten(treedef, [t[1] for t in new])
    v_n = jax.tree.unflatten(treedef, [t[2] for t in new])
    stats = dict(grad_norm=gnorm, lr=lr)
    return params_n, m_n, v_n, stats
