"""The jitted training step: loss + grad + AdamW, with optional grad-accum.

``TrainState`` is a registered-dataclass pytree so it flows through jit /
checkpointing / sharding unchanged.  Gradient accumulation runs microbatches
through a ``lax.scan`` with f32 gradient accumulators (keeps the activation
peak at one microbatch while the batch dimension stays data-sharded).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    m: Any
    v: Any
    step: jnp.ndarray     # int32 scalar


def init_state(params: Any) -> TrainState:
    m, v = opt.init_moments(params)
    return TrainState(params=params, m=m, v=v, step=jnp.int32(0))


def make_train_step(loss_fn: Callable, cfg: opt.AdamWConfig,
                    *, grad_accum: int = 1) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics dict)."""

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def micro(carry, mb):
            acc, metrics_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                acc, grads)
            metrics_acc = jax.tree.map(
                lambda s, x: s + x.astype(jnp.float32) / grad_accum,
                metrics_acc, metrics)
            return (acc, metrics_acc), None

        def split(x):
            b = x.shape[0]
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        metrics0 = jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.float32),
            jax.eval_shape(lambda: loss_fn(params, jax.tree.map(
                lambda x: x[0], mbs))[1]))
        (grads, metrics), _ = jax.lax.scan(micro, (zeros, metrics0), mbs)
        return metrics["loss"], metrics, grads

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, metrics, grads = compute_grads(state.params, batch)
        params, m, v, stats = opt.adamw_update(
            grads, state.m, state.v, state.params, state.step, cfg)
        new_state = TrainState(params=params, m=m, v=v, step=state.step + 1)
        return new_state, {**metrics, **stats}

    return train_step
