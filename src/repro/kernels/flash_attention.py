"""Pallas TPU kernel: flash attention forward (serving/prefill hot-spot).

Grid layout ``(batch x kv_head x group, q_blocks, kv_blocks)`` with the KV
axis innermost: the (qc, d) output block and the online-softmax statistics
live in VMEM scratch across the KV sweep, so HBM sees each K/V block exactly
once and the (qc, ck) logits tile never leaves VMEM — the standard
flash-attention dataflow expressed as BlockSpecs.

Causal + sliding-window masks are generated from block indices with iota
(no mask tensors in HBM).  Fully-masked future blocks are *skipped* via
``pl.when`` (the triangular schedule of the jnp path — on TPU the grid
still enumerates the block, but the body is predicated off, saving the MXU
work).

Scope: forward only — training uses the custom-VJP jnp flash in
``models/layers.py`` (a fused backward kernel is the natural next step).
Validated in interpret mode against the pure-jnp oracle in
``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      q_chunk: int, kv_chunk: int, sq: int, sk: int,
                      window: int, softcap: float, nk: int):
    qi = pl.program_id(1)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * q_chunk + jax.lax.broadcasted_iota(
        jnp.int32, (q_chunk, 1), 0)
    k_pos = ci * kv_chunk + jax.lax.broadcasted_iota(
        jnp.int32, (1, kv_chunk), 1)

    # causal frontier: skip blocks strictly above the diagonal (and, with a
    # window, blocks entirely older than the window)
    live = ci * kv_chunk <= qi * q_chunk + q_chunk - 1
    if window > 0:
        live &= (ci + 1) * kv_chunk - 1 >= qi * q_chunk - window + 1

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)                  # (qc, d)
        k = k_ref[0].astype(jnp.float32)                  # (ck, d)
        v = v_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (qc, ck)
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        mask = (q_pos >= k_pos) & (k_pos < sk) & (q_pos < sq)
        if window > 0:
            mask &= q_pos - k_pos < window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]                               # (qc, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ci == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "q_chunk", "kv_chunk",
                     "interpret"))
def flash_attention(
    q: jnp.ndarray,            # (B, Sq, H, D)
    k: jnp.ndarray,            # (B, Sk, KV, D)
    v: jnp.ndarray,            # (B, Sk, KV, D)
    *,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 256,
    kv_chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (B, Sq, H, D); causal (+ optional window / softcap), GQA."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq = -(-sq // q_chunk)
    nk = -(-sk // kv_chunk)
    pad_q = nq * q_chunk - sq
    pad_k = nk * kv_chunk - sk

    # heads-major flattening: rows of qf are (b, kv_head, group)
    qf = (q * scale).transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    grid = (b * h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _flash_fwd_kernel, q_chunk=q_chunk, kv_chunk=kv_chunk,
            sq=sq, sk=sk, window=window, softcap=softcap, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_chunk, d), lambda bh, qi, ci: (bh, qi, 0)),
            pl.BlockSpec((1, kv_chunk, d),
                         lambda bh, qi, ci, g=g: (bh // g, ci, 0)),
            pl.BlockSpec((1, kv_chunk, d),
                         lambda bh, qi, ci, g=g: (bh // g, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_chunk, d),
                               lambda bh, qi, ci: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * q_chunk, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_chunk, 1), jnp.float32),
            pltpu.VMEM((q_chunk, 1), jnp.float32),
            pltpu.VMEM((q_chunk, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    return out
