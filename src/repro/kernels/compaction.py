"""Active-case compaction for the frontier histogram kernel.

Deep in the build, the open frontier covers a tiny fraction of the training
set, but the histogram kernel's case-tile grid always streams all N cases
through HBM — O(N) traffic per superstep to count a handful of rows.  This
module gathers the cases whose node is in the open frontier into a dense
``(N_active,)`` buffer before the kernel runs, so the case-tile grid scales
with *live* cases.

Shapes must stay static under jit (the build is a ``lax.while_loop``), so
the gather size comes from a small ladder of power-of-two *buckets*: the
live count selects the smallest bucket that fits via ``lax.switch``, and
each branch traces the kernel at its own static size.  The largest bucket
is N itself and skips the gather entirely (no regression on shallow
supersteps where everything is live).

Per-superstep cost: one ``nonzero`` scan + gather (O(N) but elementwise,
~16 B/case) replaces O(N * ceil(K/block_k) * ceil(B/block_b)) kernel
traffic — a win whenever the frontier is sparse.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import act


def bucket_sizes(n_cases: int, *, min_bucket: int = 1024) -> tuple[int, ...]:
    """Static gather-size ladder: powers of two from ``min_bucket`` to N.

    The final bucket is exactly ``n_cases`` (the no-gather fallback).  A
    single-element ladder means compaction is a no-op for small problems —
    callers can skip the switch entirely.
    """
    n_cases = int(n_cases)
    min_bucket = max(8, int(min_bucket))
    if n_cases <= min_bucket:
        return (n_cases,)
    sizes = []
    b = min_bucket
    while b < n_cases:
        sizes.append(b)
        b <<= 1
    sizes.append(n_cases)
    return tuple(sizes)


def compact_frontier_histogram(
    x: jnp.ndarray,          # int32 (N, A) bins; -1 = unknown
    y: jnp.ndarray,          # int32 (N,) class labels
    w: jnp.ndarray,          # f32 (N,) case weights
    slot: jnp.ndarray,       # int32 (N,) frontier slot; -1 = not in frontier
    *,
    n_slots: int,
    n_bins: int,
    n_classes: int,
    min_bucket: int = 1024,
    block_t: int | None = None,
    block_k: int | None = None,
    block_b: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(K, A, B+1, C) weighted counts over the compacted live cases."""
    from repro.kernels import ops as kernel_ops

    x = jnp.asarray(x)
    y = jnp.asarray(y)
    w = jnp.asarray(w)
    slot = jnp.asarray(slot)
    n = x.shape[0]
    kw = dict(n_slots=n_slots, n_bins=n_bins, n_classes=n_classes,
              interpret=interpret)
    if block_k is not None:
        kw["block_k"] = block_k
    if block_b is not None:
        kw["block_b"] = block_b

    def full(_):
        return kernel_ops.frontier_histogram(
            x, y, w, slot, **(kw if block_t is None
                              else dict(kw, block_t=block_t)))

    sizes = bucket_sizes(n, min_bucket=min_bucket)
    if len(sizes) == 1:
        return full(None)

    part = slot >= 0
    n_active = jnp.sum(part.astype(jnp.int32))

    def gathered(size: int):
        def run(_):
            idx = jnp.nonzero(part, size=size, fill_value=0)[0]
            live = jnp.arange(size, dtype=jnp.int32) < n_active
            xg = act.shard_active_cases(x[idx])
            sg = act.shard_active_cases(
                jnp.where(live, slot[idx], -1).astype(jnp.int32))
            bt = min(block_t or 512, max(8, size))
            return kernel_ops.frontier_histogram(
                xg, y[idx], w[idx], sg, **dict(kw, block_t=bt))
        return run

    branches = [gathered(s) for s in sizes[:-1]] + [full]
    sel = jnp.searchsorted(jnp.asarray(sizes, jnp.int32), n_active,
                           side="left").astype(jnp.int32)
    return jax.lax.switch(sel, branches, None)
