"""Pallas TPU kernel: batched forest traversal (level-synchronous descent).

Inference over a packed :class:`~repro.infer.forest.Forest` is the serving
hot-spot: route N cases through T trees of capacity M.  A GPU port would
chase pointers with per-thread gathers; the TPU-native formulation keeps one
tree's node table plus one case block resident in VMEM and turns every
per-depth gather into a one-hot MXU matmul (the same trick as
:mod:`repro.kernels.histogram`):

    for each depth step:
        E    = onehot(node over M)                    (Nblk, M)
        vals = E @ node_tab                           (Nblk, NODE_COLS)
        # vals columns: attr, split_bin, child0, nchild, heavy, class
        Ea   = onehot(attr over A)                    (Nblk, A)
        b    = rowsum(Ea * x_block)                   (Nblk,)  case's bin
        node = route(b, vals)        # continuous / discrete / unknown

The grid is (tree, case block): each kernel instance loads its tree's
``(M, NODE_COLS)`` table once and streams ``max_depth`` descent steps over a
``(block_n, A)`` case tile, emitting the ``(block_n,)`` leaf classes.  All
table values are small integers, exact in f32 (capacities < 2**24), so the
matmul gathers are bit-faithful to :func:`repro.core.tree.descend_once`.

Routing semantics match the shared descend step exactly: continuous
attributes test ``b <= split_bin`` (child 0/1), discrete attributes index
the child by bin value, unknown values (``b < 0``) follow the precomputed
heaviest child, and leaves (``nchild == 0``) are absorbing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Column layout of the packed node table (see :meth:`Forest.node_table`).
COL_ATTR, COL_SPLIT, COL_CHILD0, COL_NCHILD, COL_HEAVY, COL_CLASS = range(6)
NODE_COLS = 8          # 6 live columns padded to 8 for sublane alignment


def _infer_kernel(tab_ref, x_ref, cont_ref, out_ref, *, max_depth: int,
                  capacity: int):
    tab = tab_ref[0].astype(jnp.float32)           # (M, NODE_COLS)
    x = x_ref[...].astype(jnp.float32)             # (Nblk, A) bins, -1 unknown
    cont = cont_ref[0, :].astype(jnp.float32)      # (A,)
    n_blk, a_dim = x.shape

    iota_m = jax.lax.broadcasted_iota(jnp.int32, (1, capacity), 1)
    iota_a = jax.lax.broadcasted_iota(jnp.float32, (1, a_dim), 1)

    def gather_cols(node):
        e = (node[:, None] == iota_m).astype(jnp.float32)   # (Nblk, M)
        return jax.lax.dot_general(
            e, tab, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # (Nblk, NODE_COLS)

    def step(_, node):
        vals = gather_cols(node)
        attr = vals[:, COL_ATTR]
        sbin = vals[:, COL_SPLIT]
        child0 = vals[:, COL_CHILD0]
        nchild = vals[:, COL_NCHILD]
        heavy = vals[:, COL_HEAVY]
        ea = (attr[:, None] == iota_a).astype(jnp.float32)  # (Nblk, A)
        b = jnp.sum(ea * x, axis=1)
        is_cont = jnp.sum(ea * cont[None, :], axis=1) > 0.5
        child = jnp.where(is_cont, jnp.where(b <= sbin, 0.0, 1.0), b)
        child = jnp.where(b < 0, heavy, child)
        child = jnp.clip(child, 0.0, jnp.maximum(nchild - 1.0, 0.0))
        nxt = (child0 + child).astype(jnp.int32)
        return jnp.where(nchild == 0, node, nxt)

    node = jnp.zeros((n_blk,), jnp.int32)
    node = jax.lax.fori_loop(0, max_depth, step, node)
    out_ref[...] = gather_cols(node)[None, :, COL_CLASS].astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("max_depth", "block_n", "interpret"))
def forest_predict(
    node_tab: jnp.ndarray,       # int32 (T, M, NODE_COLS) packed node table
    x_bins: jnp.ndarray,         # int32 (N, A) bins; -1 = unknown
    attr_is_cont: jnp.ndarray,   # bool (A,)
    *,
    max_depth: int,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (T, N) int32 leaf classes, one row per packed tree."""
    t_dim, m_dim, cols = node_tab.shape
    if cols != NODE_COLS:
        raise ValueError(f"node_tab last dim {cols} != {NODE_COLS}")
    n, a_dim = x_bins.shape
    pad_n = (-n) % block_n
    if pad_n:
        x_bins = jnp.pad(x_bins, ((0, pad_n), (0, 0)),
                         constant_values=-1)
    np_dim = n + pad_n

    grid = (t_dim, np_dim // block_n)
    out = pl.pallas_call(
        functools.partial(_infer_kernel, max_depth=max_depth,
                          capacity=m_dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m_dim, NODE_COLS), lambda t, nb: (t, 0, 0)),
            pl.BlockSpec((block_n, a_dim), lambda t, nb: (nb, 0)),
            pl.BlockSpec((1, a_dim), lambda t, nb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda t, nb: (t, nb)),
        out_shape=jax.ShapeDtypeStruct((t_dim, np_dim), jnp.int32),
        interpret=interpret,
    )(node_tab.astype(jnp.int32), x_bins.astype(jnp.int32),
      attr_is_cont.astype(jnp.int32)[None, :])
    return out[:, :n]
