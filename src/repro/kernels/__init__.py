"""Pallas kernels for the splitAtt hot-spot (+ flash attention for the LM
cells).  Callers go through :mod:`repro.kernels.ops`, which picks interpret
mode off-TPU; :mod:`repro.kernels.autotune` plans the block sizes and
:mod:`repro.kernels.compaction` keeps deep-superstep traffic proportional to
live cases.  :mod:`repro.kernels.ref` holds the pure-jnp oracles the tests
compare against.
"""
