"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import entropy


def frontier_histogram_ref(x, y, w, slot, *, n_slots: int, n_bins: int,
                           n_classes: int) -> jnp.ndarray:
    """(K, A, B+1, C) weighted counts via one flat segment-sum."""
    from repro.core.frontier import frontier_histogram_jnp
    return frontier_histogram_jnp(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(w), jnp.asarray(slot),
        n_slots=n_slots, n_bins=n_bins, n_classes=n_classes)


def forest_predict_ref(forest, x_bins, attr_is_cont, *,
                       max_depth: int | None = None) -> jnp.ndarray:
    """(T, N) leaf classes via the per-tree oracle ``tree.predict`` loop."""
    from repro.infer.forest import predict_per_tree
    return predict_per_tree(forest, x_bins, attr_is_cont, impl="ref",
                            max_depth=max_depth)


def split_gain_ref(hist, total_w, attr_is_cont, n_bins, *,
                   min_objs: float = 2.0, criterion: str = "gain"):
    """(score, split_bin) of shape (K, A) via the shared scorer."""
    return entropy.gains_from_histogram(
        jnp.asarray(hist), total_w=jnp.asarray(total_w),
        attr_is_cont=jnp.asarray(attr_is_cont),
        n_bins=jnp.asarray(n_bins), min_objs=min_objs, criterion=criterion)
