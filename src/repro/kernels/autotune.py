"""Block-size planning for the splitAtt Pallas kernels.

Both kernels are tiled over (frontier slot, bin/attribute, case) axes; the
tile sizes decide VMEM residency and therefore whether the kernels hit their
roofline.  The dominant VMEM tenants are

  histogram:  the one-hot expansion  E (block_t, block_k*block_b) f32
              plus the output window    (block_k, block_b, C) f32
  split_gain: the histogram block       (block_k, block_a, B, C) f32
              plus ~3x that in scan/entropy intermediates

``plan_blocks`` picks power-of-two tiles that keep both under a VMEM budget
(default 4 MB — half a v5e core's VMEM, leaving room for double buffering)
while never exceeding the (padded) problem extents.  Every field can be
pinned via :class:`repro.core.config.GrowConfig` (``block_*`` attributes);
``None`` means "use the heuristic".
"""

from __future__ import annotations

import dataclasses

# Conservative per-kernel VMEM budget (bytes).  ~16 MB/core physically; half
# of it so the pipeline can double-buffer input tiles.
VMEM_BUDGET = 4 << 20


def _pow2_ceil(x: int) -> int:
    x = max(1, int(x))
    return 1 << (x - 1).bit_length()


def _pow2_floor(x: int) -> int:
    x = max(1, int(x))
    return 1 << (x.bit_length() - 1)


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """Static tile sizes for one frontier problem shape.

    ``block_t/block_k/block_b`` drive the histogram kernel's
    (case, slot, bin) grid; ``block_k/block_a`` drive the split-gain
    kernel's (slot, attribute) grid.
    """
    block_t: int
    block_k: int
    block_b: int
    block_a: int


def plan_blocks(
    *,
    n_cases: int,
    n_slots: int,
    n_bins: int,          # B: the histogram kernel emits B+1 (unknown bin)
    n_classes: int,
    n_attrs: int,
    vmem_budget: int = VMEM_BUDGET,
    block_t: int | None = None,
    block_k: int | None = None,
    block_b: int | None = None,
    block_a: int | None = None,
) -> BlockPlan:
    """Choose tile sizes from the problem shape (overrides win)."""
    b1 = n_bins + 1
    c = max(1, n_classes)

    # Case tile: 512 saturates the MXU contraction; smaller problems shrink
    # to their padded extent so interpret-mode tests stay fast.
    bt = block_t or min(512, _pow2_ceil(max(8, n_cases)))

    # Bin tile: whole (padded) bin axis when it fits a lane tile, else 128
    # so each output window is lane-aligned.
    bb = block_b or min(128, _pow2_ceil(b1))

    # Attribute tile for split_gain: small A is the common case (paper
    # datasets: 7..77) — take the whole axis up to 8.
    ba = block_a or min(8, _pow2_ceil(n_attrs))

    if block_k is None:
        # Histogram: 4*bt*bk*bb (E) + 4*bk*bb*c (out) <= budget
        hist_k = (vmem_budget * 3 // 4) // (4 * bb * (bt + c))
        # Split-gain: ~4 resident copies of the (bk, ba, B, C) block
        gain_k = vmem_budget // (16 * ba * max(1, n_bins) * c)
        bk = _pow2_floor(min(hist_k, gain_k))
        bk = max(1, min(bk, 32, _pow2_ceil(n_slots)))
    else:
        bk = block_k

    return BlockPlan(block_t=bt, block_k=bk, block_b=bb, block_a=ba)


@dataclasses.dataclass(frozen=True)
class InferBlockPlan:
    """Static tile size for the forest-traversal kernel's case axis."""
    block_n: int


def plan_infer_blocks(
    *,
    n_cases: int,
    capacity: int,          # M: padded node count per packed tree
    n_attrs: int,
    node_cols: int = 8,
    vmem_budget: int = VMEM_BUDGET,
    block_n: int | None = None,
) -> InferBlockPlan:
    """Case-tile size for :mod:`repro.kernels.tree_infer` (override wins).

    The dominant VMEM tenant is the per-step one-hot expansion
    ``E (block_n, M) f32``; the node table ``(M, node_cols)`` and the case
    tile ``(block_n, A)`` ride along.  Solve 4*block_n*(M + A) +
    4*M*node_cols <= budget for the largest power-of-two block_n in
    [8, 1024], shrunk to the padded case count for small problems.
    """
    if block_n is not None:
        return InferBlockPlan(block_n=block_n)
    resident = max(1, vmem_budget - 4 * capacity * node_cols)
    bn = resident // (4 * (capacity + max(1, n_attrs)))
    bn = max(8, min(_pow2_floor(bn), 1024, _pow2_ceil(max(8, n_cases))))
    return InferBlockPlan(block_n=bn)


def plan_for_config(cfg, *, n_cases: int, n_bins: int, n_classes: int,
                    n_attrs: int) -> BlockPlan:
    """Plan from a :class:`GrowConfig` (its ``block_*`` fields pin tiles)."""
    return plan_blocks(
        n_cases=n_cases, n_slots=cfg.frontier_slots, n_bins=n_bins,
        n_classes=n_classes, n_attrs=n_attrs,
        block_t=cfg.block_t, block_k=cfg.block_k, block_b=cfg.block_b,
        block_a=cfg.block_a)
