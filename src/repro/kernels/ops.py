"""Public jit'd wrappers over the Pallas kernels.

On TPU the kernels run natively; on CPU (this container) they execute in
``interpret=True`` mode so every caller — including the frontier engine with
``impl="pallas"`` — exercises the real kernel bodies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import histogram as _histogram
from repro.kernels import split_gain as _split_gain
from repro.kernels import tree_infer as _tree_infer
from repro.kernels.autotune import plan_infer_blocks


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def frontier_histogram(x, y, w, slot, *, n_slots: int, n_bins: int,
                       n_classes: int, block_t: int = 512, block_k: int = 8,
                       block_b: int = 128,
                       interpret: bool | None = None) -> jnp.ndarray:
    """(K, A, B+1, C) weighted counts — MXU one-hot matmul kernel."""
    if interpret is None:
        interpret = _on_cpu()
    # Shrink blocks to the problem so interpret-mode tests stay fast.
    block_k = min(block_k, max(1, n_slots))
    block_b = min(block_b, n_bins + 1)
    block_t = min(block_t, max(8, x.shape[0]))
    return _histogram.frontier_histogram(
        x, y, w, slot, n_slots=n_slots, n_bins=n_bins, n_classes=n_classes,
        block_t=block_t, block_k=block_k, block_b=block_b,
        interpret=interpret)


def frontier_histogram_compact(x, y, w, slot, *, n_slots: int, n_bins: int,
                               n_classes: int, min_bucket: int = 1024,
                               block_t: int | None = None,
                               block_k: int | None = None,
                               block_b: int | None = None,
                               interpret: bool | None = None) -> jnp.ndarray:
    """Histogram kernel over the compacted live cases (bucketed gather).

    Same contract as :func:`frontier_histogram`; the case-tile grid scales
    with the open frontier's live-case count instead of N (see
    :mod:`repro.kernels.compaction`).
    """
    from repro.kernels import compaction
    return compaction.compact_frontier_histogram(
        x, y, w, slot, n_slots=n_slots, n_bins=n_bins, n_classes=n_classes,
        min_bucket=min_bucket, block_t=block_t, block_k=block_k,
        block_b=block_b, interpret=interpret)


def forest_predict(node_tab, x_bins, attr_is_cont, *, max_depth: int,
                   block_n: int | None = None,
                   interpret: bool | None = None):
    """(T, N) leaf classes — level-synchronous MXU traversal kernel."""
    if interpret is None:
        interpret = _on_cpu()
    t_dim, m_dim, cols = node_tab.shape
    plan = plan_infer_blocks(
        n_cases=x_bins.shape[0], capacity=m_dim,
        n_attrs=x_bins.shape[1], node_cols=cols, block_n=block_n)
    return _tree_infer.forest_predict(
        node_tab, x_bins, attr_is_cont, max_depth=max_depth,
        block_n=plan.block_n, interpret=interpret)


def split_gain(hist, total_w, attr_is_cont, n_bins, *, min_objs: float = 2.0,
               criterion: str = "gain", block_k: int = 8, block_a: int = 8,
               interpret: bool | None = None):
    """(score, split_bin) per (node, attribute) — fused scan/entropy kernel."""
    if interpret is None:
        interpret = _on_cpu()
    k, a_dim = hist.shape[:2]
    block_k = min(block_k, max(1, k))
    block_a = min(block_a, max(1, a_dim))
    return _split_gain.split_gain(
        hist, total_w, attr_is_cont, n_bins, min_objs=min_objs,
        criterion=criterion, block_k=block_k, block_a=block_a,
        interpret=interpret)
