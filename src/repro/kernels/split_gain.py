"""Pallas TPU kernel: fused split-gain scoring ("splitAtt" compute phase).

Given the frontier histogram ``(K, A, B, C)`` this kernel fuses, per
(node, attribute) block and entirely in VMEM:

  * the bin prefix-scan (left/right partition counts),
  * the C4.5 entropy/gain evaluation of every candidate threshold
    (continuous) or of the multiway split (discrete),
  * the known-fraction F scaling and MINOBJS validity masks,
  * the argmax over candidate bins.

One HBM read of the histogram produces the two tiny (K, A) result planes —
the roofline-optimal shape for this stage (the naive path materialises the
(K, A, B, C) cumsum and (K, A, B) gain tensors in HBM).

The kernel body calls the *same* jnp scoring functions as every other engine
(:mod:`repro.core.entropy`), so numerics match the oracle bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import entropy


def _gain_kernel(hist_ref, tw_ref, cont_ref, nbins_ref,
                 score_ref, bin_ref, *, min_objs: float, criterion: str):
    hist = hist_ref[...]                    # (Kb, Ab, B, C)
    total_w = tw_ref[:, 0]                  # (Kb,)
    attr_is_cont = cont_ref[0, :]           # (Ab,)
    n_bins = nbins_ref[0, :]                # (Ab,)
    score, split_bin = entropy.gains_from_histogram(
        hist, total_w=total_w, attr_is_cont=attr_is_cont, n_bins=n_bins,
        min_objs=min_objs, criterion=criterion)
    score_ref[...] = score
    bin_ref[...] = split_bin


@functools.partial(
    jax.jit,
    static_argnames=("min_objs", "criterion", "block_k", "block_a",
                     "interpret"))
def split_gain(
    hist: jnp.ndarray,          # f32 (K, A, B, C)
    total_w: jnp.ndarray,       # f32 (K,)
    attr_is_cont: jnp.ndarray,  # bool (A,)
    n_bins: jnp.ndarray,        # int32 (A,)
    *,
    min_objs: float = 2.0,
    criterion: str = "gain",
    block_k: int = 8,
    block_a: int = 8,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns ``(score, split_bin)`` of shape (K, A); score -inf = invalid."""
    k, a_dim, b_dim, c_dim = hist.shape
    pad_k = (-k) % block_k
    pad_a = (-a_dim) % block_a
    if pad_k or pad_a:
        hist = jnp.pad(hist, ((0, pad_k), (0, pad_a), (0, 0), (0, 0)))
        total_w = jnp.pad(total_w, (0, pad_k))
        attr_is_cont = jnp.pad(attr_is_cont, (0, pad_a))
        n_bins = jnp.pad(n_bins, (0, pad_a), constant_values=1)
    kp, ap = k + pad_k, a_dim + pad_a

    # scalar-ish operands as 2-D rows/cols (TPU wants >= 2-D layouts)
    tw2 = total_w[:, None]
    cont2 = attr_is_cont[None, :]
    nb2 = n_bins[None, :].astype(jnp.int32)

    grid = (kp // block_k, ap // block_a)
    score, split_bin = pl.pallas_call(
        functools.partial(_gain_kernel, min_objs=min_objs,
                          criterion=criterion),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, block_a, b_dim, c_dim),
                         lambda kb, ab: (kb, ab, 0, 0)),
            pl.BlockSpec((block_k, 1), lambda kb, ab: (kb, 0)),
            pl.BlockSpec((1, block_a), lambda kb, ab: (0, ab)),
            pl.BlockSpec((1, block_a), lambda kb, ab: (0, ab)),
        ],
        out_specs=(
            pl.BlockSpec((block_k, block_a), lambda kb, ab: (kb, ab)),
            pl.BlockSpec((block_k, block_a), lambda kb, ab: (kb, ab)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((kp, ap), jnp.float32),
            jax.ShapeDtypeStruct((kp, ap), jnp.int32),
        ),
        interpret=interpret,
    )(hist, tw2, cont2, nb2)
    return score[:k, :a_dim], split_bin[:k, :a_dim]
