"""Pallas TPU kernel: frontier histogram via one-hot MXU matmuls.

The splitAtt hot-spot of the SPMD tree engine is building the
``(K nodes, A attrs, B+1 bins, C classes)`` weighted-count tensor from N
cases.  A GPU port would scatter-add into gmem atomics; the TPU-native
formulation turns the scatter into a matmul so the MXU does the counting:

    for each case tile T and attribute a:
        E  = onehot( (slot, bin) -> local row )         (T, Kblk*Bblk)
        Yw = onehot(class) * weight                     (T, C)
        hist_block += E^T @ Yw                          (Kblk*Bblk, C)

The grid is (K blocks, A, B blocks, case tiles) with the case-tile axis
innermost, so each output block stays resident in VMEM while every case tile
streams through HBM exactly once per (K,B) window.

Unknown values occupy the extra bin index B (consumed by splitPost for the
heaviest-child routing).  Cases whose node is not in the frontier carry
slot = -1 and fall outside every window.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(x_ref, y_ref, w_ref, slot_ref, out_ref, *,
                 block_k: int, block_b: int, n_classes: int):
    kb = pl.program_id(0)
    bb = pl.program_id(2)
    t = pl.program_id(3)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[:, 0]                      # (T,) bin of this attribute
    sl = slot_ref[:]                      # (T,) frontier slot (-1 = inactive)
    yv = y_ref[:]
    wv = w_ref[:].astype(jnp.float32)

    k0 = kb * block_k
    b0 = bb * block_b
    in_win = ((sl >= k0) & (sl < k0 + block_k)
              & (xb >= b0) & (xb < b0 + block_b))
    rows = (sl - k0) * block_b + (xb - b0)          # (T,) local row id
    rows = jnp.where(in_win, rows, -1)

    n_rows = block_k * block_b
    e = (rows[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, n_rows), 1)
         ).astype(jnp.float32)                       # (T, Kblk*Bblk)
    cls = (yv[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, n_classes), 1)).astype(jnp.float32)
    yw = cls * wv[:, None]                           # (T, C)

    acc = jax.lax.dot_general(
        e, yw, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Kblk*Bblk, C)
    out_ref[...] += acc.reshape(block_k, 1, block_b, n_classes)


@functools.partial(
    jax.jit,
    static_argnames=("n_slots", "n_bins", "n_classes", "block_t", "block_k",
                     "block_b", "interpret"))
def frontier_histogram(
    x: jnp.ndarray,          # int32 (N, A) bins; -1 = unknown
    y: jnp.ndarray,          # int32 (N,) class labels
    w: jnp.ndarray,          # f32 (N,) case weights
    slot: jnp.ndarray,       # int32 (N,) frontier slot; -1 = not in frontier
    *,
    n_slots: int,
    n_bins: int,             # B; the kernel emits B+1 (unknown bin last)
    n_classes: int,
    block_t: int = 512,
    block_k: int = 8,
    block_b: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (K, A, B+1, C) float32 weighted counts."""
    n, a_dim = x.shape
    b1 = n_bins + 1

    # Unknown values -> bin index B; pad every shape to its block multiple.
    x = jnp.where(x >= 0, x, n_bins).astype(jnp.int32)
    pad_n = (-n) % block_t
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
        y = jnp.pad(y, (0, pad_n))
        w = jnp.pad(w, (0, pad_n))
        slot = jnp.pad(slot, (0, pad_n), constant_values=-1)
    pad_k = (-n_slots) % block_k
    pad_b = (-b1) % block_b
    kp, bp = n_slots + pad_k, b1 + pad_b

    grid = (kp // block_k, a_dim, bp // block_b, (n + pad_n) // block_t)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, block_k=block_k, block_b=block_b,
                          n_classes=n_classes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, 1), lambda kb, a, bb, t: (t, a)),
            pl.BlockSpec((block_t,), lambda kb, a, bb, t: (t,)),
            pl.BlockSpec((block_t,), lambda kb, a, bb, t: (t,)),
            pl.BlockSpec((block_t,), lambda kb, a, bb, t: (t,)),
        ],
        out_specs=pl.BlockSpec((block_k, 1, block_b, n_classes),
                               lambda kb, a, bb, t: (kb, a, bb, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, a_dim, bp, n_classes),
                                       jnp.float32),
        interpret=interpret,
    )(x, y.astype(jnp.int32), w.astype(jnp.float32), slot.astype(jnp.int32))
    return out[:n_slots, :, :b1, :]
