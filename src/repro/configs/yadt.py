"""The paper's own workload as a first-class config: YaDT-FF tree growth.

``--arch yadt`` selects the SPMD frontier engine over the SyD10M9A schema
(paper Table 1).  The "train step" of this architecture is one frontier
superstep; shapes reuse the ShapeSpec machinery with seq_len standing in
for the case count processed per superstep.
"""

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.config import GrowConfig


@dataclasses.dataclass(frozen=True)
class YaDTWorkload:
    n_cases: int = 10_000_000
    n_attrs: int = 9
    n_bins: int = 256
    n_classes: int = 2
    max_children: int = 20          # widest discrete split (car: 20 values)
    grow: GrowConfig = GrowConfig(max_nodes=1 << 18, frontier_slots=256)


WORKLOAD = YaDTWorkload()

CONFIG = ModelConfig(
    name="yadt", family="tree",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=0,
    notes="paper technique itself; dry-run lowers one frontier superstep "
          "with cases sharded over data x attributes over model (NAP).",
)
