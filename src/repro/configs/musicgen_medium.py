"""musicgen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

The EnCodec tokenizer/vocoder is a STUB: input_specs() provides the token
stream (train) or precomputed frame embeddings (frontend early-fusion).
MHA (kv == heads == 24), sinusoidal positions, layernorm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    block_pattern=("global",), norm="layernorm", act="gelu",
    pos="sinusoidal",
    frontend="audio", frontend_tokens=0,
    notes="full attention => long_500k skipped.",
)
