"""yi-6b [arXiv:2403.04652] — llama-architecture GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi_6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    block_pattern=("global",),
    notes="pure full attention => long_500k skipped.",
)
