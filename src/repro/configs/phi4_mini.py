"""phi4-mini-3.8b [arXiv:2412.08905] — dense RoPE SwiGLU GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4_mini", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=200064,
    block_pattern=("global",),
    notes="pure full attention => long_500k skipped.",
)
