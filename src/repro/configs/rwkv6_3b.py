"""rwkv6-3b "Finch" [arXiv:2404.05892] — attention-free SSM."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    block_pattern=("rwkv",), pos="none",
    supports_long_context=True,
    notes="data-dependent decay; O(1) state => runs long_500k.",
)
