"""gemma2-9b [arXiv:2408.00118] — alternating local/global, logit softcaps."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2_9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    block_pattern=("local", "global"), window=4096,
    logit_softcap=30.0, attn_softcap=50.0,
    supports_long_context=True,
    notes="1:1 local:global; long_500k borderline (21 global layers hold "
          "full KV, seq-sharded) — see roofline table.",
)
