"""llava-next-34b [hf:llava-hf/llava-v1.6; unverified] — VLM.

Backbone per the assignment (Yi-34B-like dense GQA).  The anyres vision
tower is a STUB: input_specs() provides precomputed patch embeddings that
early-fuse into the first `frontend_tokens` positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava_next_34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    block_pattern=("global",),
    frontend="vision", frontend_tokens=1152,
    notes="anyres tiling stub: 1152 patch embeddings (2x 24x24 tiles).",
)
