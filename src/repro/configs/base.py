"""Config system: model architecture + input-shape registry (``--arch <id>``).

Every assigned architecture is one ``ModelConfig`` in its own module under
``repro/configs``; ``registry()`` collects them.  Shape cells are the four
assigned input shapes; ``cells(cfg)`` yields the (arch x shape) pairs that
are runnable for the architecture (``long_500k`` needs sub-quadratic
attention — see DESIGN.md §5 for the skip rationale per arch).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Iterable

ARCH_IDS = (
    "phi35_moe", "llama4_scout", "llava_next_34b", "rwkv6_3b", "phi4_mini",
    "gemma3_4b", "gemma2_9b", "yi_6b", "musicgen_medium", "recurrentgemma_2b",
    "yadt",      # the paper's own workload as a first-class config
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio|tree
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block_pattern: tuple[str, ...] = ("global",)  # cycled: global|local|rwkv|rglru
    window: int = 4096
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"
    act: str = "silu"
    pos: str = "rope"                 # rope|sinusoidal|none
    tie_embeddings: bool = False
    frontend: str | None = None       # None|vision|audio
    frontend_tokens: int = 0
    lru_width: int = 0
    conv_width: int = 4
    supports_long_context: bool = False
    dtype: str = "bfloat16"
    notes: str = ""

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind in ("global", "local"):
                total += d * self.head_dim * (self.n_heads * 2
                                              + self.n_kv_heads * 2)
            elif kind == "rwkv":
                total += 5 * d * d + 2 * 64 * d      # time-mix + decay lora
            elif kind == "rglru":
                w = self.lru_width or d
                total += 3 * d * w + 2 * w * w + self.conv_width * w
            if kind == "rwkv":
                total += 2 * d * f + d * d           # channel-mix
            elif self.is_moe:
                total += self.n_experts * 3 * d * f \
                    + self.n_shared_experts * 3 * d * f + d * self.n_experts
            else:
                total += 3 * d * f
            total += 2 * d                           # norms
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = (self.n_experts - self.experts_per_token) * 3 * d * f
        return self.param_count() - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def registry() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def runnable_shapes(cfg: ModelConfig) -> Iterable[ShapeSpec]:
    for shape in SHAPES.values():
        if shape.name == "long_500k" and not cfg.supports_long_context:
            continue   # quadratic-attention arch: skip per brief, see DESIGN.md
        yield shape


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=min(cfg.n_layers, 2 * max(1, len(cfg.block_pattern))),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        lru_width=128 if cfg.lru_width else 0,
        frontend_tokens=min(cfg.frontend_tokens, 8),
        window=min(cfg.window, 64) if cfg.window else 0,
    )
    if cfg.family == "audio":
        base["n_kv_heads"] = base["n_heads"]      # musicgen is MHA
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
