"""Per-architecture configs; see base.registry()."""
