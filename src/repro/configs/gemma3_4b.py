"""gemma3-4b [hf:google/gemma-3; unverified] — 5:1 local:global, 128k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    supports_long_context=True,
    notes="5:1 local:global; long_500k runs with window-bounded local KV "
          "and seq-sharded global KV (1 in 6 layers).",
)
