"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Text backbone only ("early fusion" multimodality is out of the assigned
scope — no frontend listed).  MoE 16 routed experts top-1 plus one shared
expert per layer (Llama-4 uses a shared expert alongside the routed one).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4_scout", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    n_experts=16, experts_per_token=1, n_shared_experts=1,
    block_pattern=("global",),
    notes="MoE 16e top-1 + shared expert; chunked-attention long context "
          "not modelled => long_500k skipped (quadratic global attention).",
)
