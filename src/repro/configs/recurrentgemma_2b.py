"""recurrentgemma-2b [arXiv:2402.19427] — RG-LRU + local attention, 1:2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"), window=2048,
    lru_width=2560,
    supports_long_context=True,
    notes="2 RG-LRU : 1 local-attn; O(1)/windowed state => runs long_500k.",
)
