"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi35_moe", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    n_experts=16, experts_per_token=2,
    block_pattern=("global",),
    notes="16 experts top-2 every layer; GQA kv=8.",
)
