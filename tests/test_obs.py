"""Observability layer: tracer, metrics registry, report, instrumented runs."""

import json
import threading

import numpy as np
import pytest

from conftest import make_tree_dataset

from repro.core import farm_build, frontier
from repro.core.config import GrowConfig
from repro.core.farm import FaultPolicy
from repro.core.faults import FaultInjector, FaultSpec
from repro.core.tree import trees_equal
from repro.obs import report
from repro.obs.metrics import DEFAULT_BUCKETS, Registry
from repro.obs.trace import NULL, Tracer, _NULL_SPAN


# ---------------------------------------------------------------- tracer


def test_span_nesting_emits_one_complete_event_per_span():
    tr = Tracer()
    with tr.span("outer", step=0):
        with tr.span("inner"):
            pass
        with tr.span("inner"):
            pass
    evs = [e for e in tr.events if e.get("ph") == "X"]
    assert [e["name"] for e in evs] == ["inner", "inner", "outer"]
    outer = evs[-1]
    assert outer["args"] == {"step": 0}
    # children are contained within the parent's interval
    for inner in evs[:2]:
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0


def test_disabled_tracer_is_a_noop():
    assert NULL.enabled is False
    assert NULL.span("x") is _NULL_SPAN
    with NULL.span("x", a=1):
        NULL.instant("ev", k=2)
        NULL.counter("c", v=3.0)
        NULL.begin("req", id=1)
        NULL.end("req", id=1)
    assert NULL.events == []


def test_chrome_export_is_perfetto_shaped(tmp_path):
    tr = Tracer()
    with tr.span("phase"):
        tr.instant("blip", detail="x")
    tr.counter("load", weight=3.0)
    tr.begin("request", id=7, weight=12)
    tr.end("request", id=7, outcome="ok")
    path = tr.save(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"X", "i", "C", "b", "e", "M"} <= phases
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] != "M":
            assert "ts" in e
    b = next(e for e in evs if e["ph"] == "b")
    en = next(e for e in evs if e["ph"] == "e")
    assert b["id"] == en["id"] == 7 and b["cat"] == en["cat"] == "async"


def test_tracer_assigns_one_lane_per_thread():
    tr = Tracer()

    barrier = threading.Barrier(3)       # keep idents from being recycled

    def work():
        barrier.wait()
        with tr.span("t"):
            pass
        barrier.wait()

    threads = [threading.Thread(target=work) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    with tr.span("main"):
        pass
    tids = {e["tid"] for e in tr.events if e["ph"] == "X"}
    assert len(tids) == 4
    meta = [e for e in tr.events if e["ph"] == "M"]
    assert {e["tid"] for e in meta} == {e["tid"] for e in tr.events
                                        if e["ph"] == "X"}


def test_span_summary_aggregates_by_name():
    tr = Tracer()
    for _ in range(3):
        with tr.span("step"):
            pass
    s = tr.span_summary()["step"]
    assert s["count"] == 3
    assert s["total_us"] >= s["max_us"] >= 0
    assert s["mean_us"] == pytest.approx(s["total_us"] / 3)


# ---------------------------------------------------------------- metrics


def test_counter_labels_are_independent_series():
    reg = Registry()
    c = reg.counter("farm_events_total", "events")
    c.inc(event="retry")
    c.inc(event="retry")
    c.inc(event="quarantine")
    assert c.value(event="retry") == 2
    assert c.value(event="quarantine") == 1
    assert c.value(event="nope") == 0
    snap = reg.snapshot()["farm_events_total"]
    assert snap["kind"] == "counter"
    got = {tuple(s["labels"].items()): s["value"] for s in snap["series"]}
    assert got == {(("event", "retry"),): 2.0,
                   (("event", "quarantine"),): 1.0}


def test_counter_rejects_negative_increment():
    with pytest.raises(ValueError):
        Registry().counter("c").inc(-1)


def test_registry_is_idempotent_and_kind_checked():
    reg = Registry()
    a = reg.counter("m", "first")
    b = reg.counter("m", "second help ignored")
    assert a is b and a.help == "first"
    with pytest.raises(TypeError):
        reg.gauge("m")
    with pytest.raises(TypeError):
        reg.histogram("m")


def test_gauge_set_and_inc():
    g = Registry().gauge("load")
    g.set(5.0, worker=0)
    g.inc(2.5, worker=0)
    g.set(1.0, worker=1)
    assert g.value(worker=0) == 7.5
    assert g.value(worker=1) == 1.0


def test_histogram_buckets_and_quantiles():
    reg = Registry()
    h = reg.histogram("lat", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 0.7, 5.0, 50.0, 5000.0):
        h.observe(v)
    snap = reg.snapshot()["lat"]["series"][0]
    assert snap["counts"] == [2, 1, 1, 1]        # last = +inf overflow
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(5056.2)
    assert h.quantile(0.5) == 10.0       # 3rd of 5 obs lands in (1, 10]
    assert h.quantile(0.9) == float("inf")
    assert np.isnan(h.quantile(0.5, other="series"))


def test_default_buckets_sorted():
    assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)


def test_registry_reset():
    reg = Registry()
    reg.counter("x").inc()
    reg.reset()
    assert reg.snapshot() == {}
    assert reg.get("x") is None


# ----------------------------------------------------------------- report


def test_report_renders_empty_and_full():
    assert "no observability data" in report.render()
    tr = Tracer()
    reg = Registry()
    with tr.span("superstep"):
        pass
    tr.counter("w0.queued_weight", weight=2.0)
    reg.counter("farm_events_total", "e").inc(event="retry")
    reg.histogram("engine_queue_wait_ticks", "w").observe(3.0)
    txt = report.render(tracer=tr, metrics=reg,
                        farm_stats={"n_workers": 2, "tasks": 5, "retries": 1,
                                    "worker_busy_s": [0.5, 0.25],
                                    "worker_tasks": [3, 2],
                                    "emitter_busy_s": 0.1})
    for needle in ("superstep", "w0.queued_weight", "farm_events_total",
                   "engine_queue_wait_ticks", "p50"):
        assert needle in txt


# ------------------------------------------------- instrumented runtimes


def test_traced_frontier_build_matches_untraced():
    ds = make_tree_dataset(np.random.default_rng(11), n=240)
    cfg = GrowConfig(max_depth=5)
    plain = frontier.build(ds, cfg)
    tr = Tracer()
    reg = Registry()
    traced, stats = frontier.build(ds, cfg, collect_stats=True,
                                   tracer=tr, metrics=reg)
    assert trees_equal(plain, traced)

    names = {e["name"] for e in tr.events if e["ph"] == "X"}
    assert {"superstep", "splitPre", "splitAtt", "splitPost"} <= names
    summ = tr.span_summary()
    n_steps = len(stats)
    assert summ["superstep"]["count"] == n_steps
    assert summ["splitAtt"]["count"] == n_steps
    snap = reg.snapshot()
    assert snap["frontier_supersteps_total"]["series"][0]["value"] == n_steps
    phase = snap["frontier_phase_seconds"]["series"]
    assert {tuple(s["labels"].items())[0][1] for s in phase} == \
        {"splitPre", "splitAtt", "splitPost"}
    assert all(s["count"] == n_steps for s in phase)


def test_traced_farm_chaos_build_matches_oracle(tmp_path):
    ds = make_tree_dataset(np.random.default_rng(5), n=220)
    cfg = GrowConfig(max_depth=6)
    oracle = farm_build.build(ds, cfg, n_workers=1)
    tr = Tracer()
    reg = Registry()
    inj = FaultInjector(seed=3, spec=FaultSpec(crash_p=0.25))
    stats = {}
    tree = farm_build.build(ds, cfg, n_workers=4, injector=inj,
                            fault=FaultPolicy(max_retries=8, backoff_base=0),
                            stats_out=stats, tracer=tr, metrics=reg)
    assert trees_equal(oracle, tree)
    assert stats["retries"] > 0

    names = {e["name"] for e in tr.events}
    assert {"task", "emitter", "task.dispatch", "task.retry"} <= names
    snap = reg.snapshot()
    events = {s["labels"]["event"]: s["value"]
              for s in snap["farm_events_total"]["series"]}
    assert events.get("retries") == stats["retries"]
    assert snap["farm_tasks_done_total"]["series"][0]["value"] == \
        sum(stats["worker_tasks"])
    # trace survives a JSON round-trip (Perfetto-loadable)
    path = tr.save(str(tmp_path / "farm.json"))
    assert json.loads(open(path).read())["traceEvents"]


def test_tracing_disabled_leaves_no_residue():
    ds = make_tree_dataset(np.random.default_rng(2), n=200)
    cfg = GrowConfig(max_depth=4)
    n0 = len(NULL.events)
    a = frontier.build(ds, cfg)
    b = frontier.build(ds, cfg, tracer=NULL)
    assert trees_equal(a, b)
    assert len(NULL.events) == n0
