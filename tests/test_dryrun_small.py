"""Small-mesh dry-run in a subprocess (so the fake device count never leaks
into this test process).  Proves lower+compile coherence of the sharding
config on a miniature (2, 4) mesh for representative cells."""

import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import dataclasses
    import jax
    import jax.numpy as jnp

    from repro.configs import base as cfgbase
    from repro.launch.mesh import make_mesh_compat
    from repro.launch.specs import make_cell, lower_cell
    from repro.launch import roofline as rl

    mesh = make_mesh_compat((2, 4), ("data", "model"))

    # shrink shapes so the tiny mesh compiles in seconds
    cfgbase.SHAPES = {
        "train_4k": cfgbase.ShapeSpec("train_4k", 128, 8, "train"),
        "prefill_32k": cfgbase.ShapeSpec("prefill_32k", 256, 4, "prefill"),
        "decode_32k": cfgbase.ShapeSpec("decode_32k", 256, 8, "decode"),
        "long_500k": cfgbase.ShapeSpec("long_500k", 512, 1, "decode"),
    }
    reduced = {a: cfgbase.reduced(cfgbase.get_config(a))
               for a in cfgbase.ARCH_IDS if a != "yadt"}
    cfgbase.get_config = lambda a: reduced[a]

    out = {}
    for arch, shape in [("yi_6b", "train_4k"), ("phi35_moe", "train_4k"),
                        ("gemma2_9b", "decode_32k"),
                        ("rwkv6_3b", "long_500k"),
                        ("recurrentgemma_2b", "prefill_32k")]:
        cell = make_cell(arch, shape, mesh)
        compiled = lower_cell(cell, mesh).compile()
        r = rl.analyze(compiled, arch=arch, shape=shape, mesh_desc="2x4",
                       n_devices=8)
        out[f"{arch}/{shape}"] = dict(
            flops=r.device_flops, coll=r.device_coll_bytes,
            mem=compiled.memory_analysis().temp_size_in_bytes)
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_small_mesh_cells_compile():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=1200, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                           "HOME": "/root",
                           # skip the libtpu probe (60 s timeout when the
                           # host has the plugin but no TPU attached)
                           "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    results = json.loads(line[len("RESULT"):])
    assert len(results) == 5
    for key, r in results.items():
        assert r["flops"] > 0, key
        assert r["coll"] > 0, f"{key}: sharded step must communicate"
