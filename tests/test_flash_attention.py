"""Flash attention (fwd + custom-VJP bwd) vs naive reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers
from repro.models.layers import AttnSpec


def naive(q, k, v, spec, q_offset=0):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d).astype(jnp.float32) / np.sqrt(d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    if spec.softcap > 0:
        logits = jnp.tanh(logits / spec.softcap) * spec.softcap
    qp = q_offset + jnp.arange(sq)
    kp = jnp.arange(k.shape[1])
    mask = qp[:, None] >= kp[None, :]
    if spec.window > 0:
        mask &= qp[:, None] - kp[None, :] < spec.window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


CASES = [
    dict(window=0, cap=0.0, s=24, qc=8, kc=8),
    dict(window=5, cap=0.0, s=24, qc=8, kc=8),
    dict(window=0, cap=30.0, s=24, qc=8, kc=8),
    dict(window=7, cap=50.0, s=24, qc=8, kc=8),
    dict(window=0, cap=0.0, s=30, qc=16, kc=8),   # ragged chunking
    dict(window=0, cap=0.0, s=17, qc=8, kc=16),   # pad both ways
]


@pytest.mark.parametrize("case", CASES)
def test_forward_and_grad_match_naive(case):
    rng = np.random.default_rng(0)
    B, S, H, KV, D = 2, case["s"], 4, 2, 16
    spec = AttnSpec(n_heads=H, n_kv_heads=KV, head_dim=D, d_model=64,
                    window=case["window"], softcap=case["cap"],
                    dtype=jnp.float32)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)

    flash = lambda *a: layers.blockwise_attention(
        *a, spec=spec, q_chunk=case["qc"], kv_chunk=case["kc"])
    np.testing.assert_allclose(np.asarray(flash(q, k, v)),
                               np.asarray(naive(q, k, v, spec)),
                               atol=2e-5, rtol=1e-5)
    f1 = lambda *a: jnp.sum(jnp.sin(flash(*a)))
    f2 = lambda *a: jnp.sum(jnp.sin(naive(*a, spec)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-4)


def test_decode_attention_matches_naive_last_row():
    rng = np.random.default_rng(1)
    B, S, H, KV, D = 3, 20, 4, 2, 8
    spec = AttnSpec(n_heads=H, n_kv_heads=KV, head_dim=D, d_model=32,
                    window=0, dtype=jnp.float32)
    q = jnp.asarray(rng.normal(0, 1, (B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    pos = jnp.array([5, 10, 19], jnp.int32)      # per-row positions
    out = layers.decode_attention(q, k, v, pos, spec=spec)
    for i, p in enumerate([5, 10, 19]):
        kk = k[i:i+1, :p+1]
        vv = v[i:i+1, :p+1]
        qq = jnp.concatenate([jnp.zeros((1, p, H, D), jnp.float32),
                              q[i:i+1]], axis=1)
        want = naive(qq, kk, vv, spec)[0, -1]
        np.testing.assert_allclose(np.asarray(out[i, 0]), np.asarray(want),
                                   atol=2e-5, rtol=1e-4)


def test_unrolled_scan_equals_scanned():
    from repro.utils import scan as uscan
    rng = np.random.default_rng(2)
    B, S, H, KV, D = 2, 32, 4, 2, 8
    spec = AttnSpec(n_heads=H, n_kv_heads=KV, head_dim=D, d_model=32,
                    dtype=jnp.float32)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, D)), jnp.float32)
    a = layers.blockwise_attention(q, k, v, spec=spec, q_chunk=8, kv_chunk=8)
    with uscan.unrolled():
        b = layers.blockwise_attention(q, k, v, spec=spec, q_chunk=8,
                                       kv_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
