"""Partitioning rules: divisibility safety + layout intent."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import base as cfgbase
from repro.models.model import build_model
from repro.sharding import partitioning as part


class FakeMesh:
    """Just enough Mesh surface for the rule functions."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        return int(np.prod(list(self.shape.values())))


MESH = FakeMesh({"data": 16, "model": 16})
POD_MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _spec(name, shape, mesh=MESH):
    path = (jax.tree_util.DictKey(name),)
    leaf = jax.ShapeDtypeStruct(shape, jax.numpy.float32)
    return part.param_pspec(path, leaf, mesh)


def test_generic_2d_zero3_plus_tp():
    assert _spec("wq", (4096, 4096)) == P("data", "model")


def test_indivisible_dims_stay_replicated():
    assert _spec("wq", (4090, 4096)) == P(None, "model")
    assert _spec("wq", (4096, 33)) == P("data", None)
    assert _spec("mu", (5, 33)) == P(None, None)


def test_embed_and_head_vocab_parallel():
    assert _spec("embed", (262144, 2560)) == P("model", "data")
    assert _spec("lm_head", (2560, 262144)) == P("data", "model")


def test_expert_weights_ep():
    assert _spec("w_gate", (16, 4096, 6400)) == P("model", "data", None)


def test_1d_replicated():
    assert _spec("scale", (4096,)) == P()


@pytest.mark.parametrize("arch", ["phi35_moe", "gemma3_4b", "rwkv6_3b",
                                  "recurrentgemma_2b", "musicgen_medium"])
def test_all_param_rules_divide(arch):
    """Every full-config param gets a spec whose sharded dims divide."""
    cfg = cfgbase.get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))

    def check(path, leaf):
        spec = part.param_pspec(path, leaf, MESH)
        for dim, axes in zip(leaf.shape, spec):
            if axes is None:
                continue
            assert dim % part.axis_size(MESH, axes) == 0, (path, leaf.shape,
                                                           spec)
        return leaf

    jax.tree_util.tree_map_with_path(check, params)


def test_batch_axes_single_vs_multipod():
    assert part.batch_axes(MESH) == ("data",)
    assert part.batch_axes(POD_MESH) == ("pod", "data")


def test_shard_active_cases_pins_dp_dim0(monkeypatch):
    """Compacted live-case buffers keep dim0 on the DP axes (layout intent)."""
    from repro.sharding import act

    seen = []
    monkeypatch.setattr(act, "_constrain",
                        lambda x, spec: seen.append(spec) or x)
    x2 = np.zeros((128, 9), np.int32)
    with act.activation_sharding(("data",), 16):
        act.shard_active_cases(x2)
        assert seen[-1] == P(("data",), None)
        act.shard_active_cases(np.zeros((129,), np.float32))
        assert seen[-1] == P(None)            # indivisible -> replicated
    n = len(seen)
    with act.activation_sharding(("data",), 16, yadt_compact=False):
        act.shard_active_cases(x2)
    assert len(seen) == n                     # knob off -> no pin
    act.shard_active_cases(x2)                # no context -> no-op
    assert len(seen) == n


def test_cache_pspec_seq_sharding():
    cfg = cfgbase.get_config("gemma2_9b")
    # global layer (odd index in (local, global) pattern)
    spec = part.cache_pspec(cfg, MESH, 1, "k", (128, 32768, 8, 256),
                            long=False)
    assert spec == P(("data",), "model", None, None)
    # batch-1 long context: sequence takes every axis
    spec = part.cache_pspec(cfg, MESH, 1, "k", (1, 524288, 8, 256),
                            long=True)
    assert spec == P(None, ("data", "model"), None, None)
    # local layer ring stays replicated on seq
    spec = part.cache_pspec(cfg, MESH, 0, "k", (128, 4096, 8, 256),
                            long=False)
    assert spec == P(("data",), None, None, None)
