"""Scheduler policies, threaded farm semantics, simulator invariants."""

import numpy as np
import pytest

from repro.core import simulate
from repro.core.farm import Farm
from repro.core.scheduler import DRR, OD, WS, QueueState, make_policy


def views(specs):
    return [QueueState(tasks=t, weight=w, cap=c) for t, w, c in specs]


def test_ws_picks_least_weight():
    ws = WS()
    assert ws.pick(5, views([(1, 10, 4), (2, 3, 4), (1, 7, 4)])) == 1


def test_ws_skips_full_queues():
    ws = WS()
    assert ws.pick(5, views([(4, 0, 4), (2, 99, 4)])) == 1
    assert ws.pick(5, views([(4, 0, 4), (4, 0, 4)])) is None


def test_drr_round_robin_skips_full():
    drr = DRR()
    assert drr.pick(1, views([(0, 0, 4), (0, 0, 4)])) == 0
    assert drr.pick(1, views([(0, 0, 4), (0, 0, 4)])) == 1
    assert drr.pick(1, views([(4, 0, 4), (0, 0, 4)])) == 1


def test_od_is_capacity_one():
    od = make_policy("od")
    assert od.forced_capacity == 1


def test_farm_feedback_conservation():
    """Every emitted task returns exactly once through the feedback channel."""
    seen = []

    def emitter(task, send):
        if task is None:
            for i in range(25):
                send(i, weight=float(i + 1))
        else:
            seen.append(task)
            if task % 7 == 0 and task > 0 and task < 20:
                send(task + 100, weight=1.0)   # D&C: children from feedback

    farm = Farm(4, policy=WS())
    stats = farm.run(emitter, lambda x: x)
    expect = 25 + len([t for t in range(25) if t % 7 == 0 and 0 < t < 20])
    assert len(seen) == expect
    assert sum(stats["worker_tasks"]) == expect


def _trace(depth=6, fanout=2, r0=1000):
    """Synthetic balanced task DAG."""
    trace, nid = [], 0
    def grow(parent, r, d):
        nonlocal nid
        me = nid; nid += 1
        nch = fanout if d < depth else 0
        trace.append(dict(node_id=me, parent=parent, r=max(int(r), 1), c=4,
                          n_children=nch, depth=d))
        for _ in range(nch):
            grow(me, r / fanout, d + 1)
    grow(-1, r0, 0)
    return trace


def test_simulator_speedup_monotone_and_bounded():
    trace = _trace()
    cm = simulate.CostModel(kappa=1e-6)
    prev = 0.0
    for w in (1, 2, 4, 8):
        r = simulate.simulate(trace, n_workers=w, strategy="nap",
                              policy="ws", cost=cm)
        assert r.speedup <= w + 0.05          # no superlinear in the model
        assert r.speedup >= prev - 0.1        # monotone non-decreasing
        prev = r.speedup


def test_simulator_work_conservation():
    trace = _trace()
    cm = simulate.CostModel(kappa=1e-6, emit_overhead=0.0, task_fixed=0.0)
    r = simulate.simulate(trace, n_workers=3, strategy="np", policy="ws",
                          cost=cm)
    # all node work must appear as worker busy time (NP: 1 task per node)
    assert sum(r.worker_busy) == pytest.approx(r.seq_time, rel=1e-6)
    assert r.makespan >= r.seq_time / 3 - 1e-9


def test_nap_beats_np_on_deep_chains():
    # a root-heavy tree: NP serialises on the root, NAP splits attributes
    trace = [dict(node_id=0, parent=-1, r=100_000, c=8, n_children=2,
                  depth=0),
             dict(node_id=1, parent=0, r=50_000, c=8, n_children=0, depth=1),
             dict(node_id=2, parent=0, r=50_000, c=8, n_children=0, depth=1)]
    cm = simulate.CostModel(kappa=1e-7)
    np_r = simulate.simulate(trace, n_workers=8, strategy="np", cost=cm)
    nap_r = simulate.simulate(trace, n_workers=8, strategy="nap", cost=cm)
    assert nap_r.speedup > np_r.speedup


def test_cost_models_monotone_in_r():
    from repro.core.cost_models import build_att_test
    for model in ("alpha", "nlogn", "nsq"):
        prev = False
        for r in (10, 100, 1000, 10_000, 100_000):
            cur = bool(build_att_test(model, n_total_cases=50_000.0,
                                      r=float(r), c=8.0))
            assert cur >= prev    # once True, stays True (paper property)
            prev = cur
