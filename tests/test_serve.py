"""Serving engine: continuous batching correctness + WS scheduling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models.model import build_model
from repro.serve.engine import Replica, Request, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = cfgbase.reduced(cfgbase.get_config("yi_6b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_matches_manual_greedy_decode(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)

    logits, cache = jax.jit(lambda p, t: model.prefill(p, t, max_seq=64))(
        params, jnp.asarray(prompt)[None])
    toks = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(4):
        l, cache = jax.jit(model.decode_step)(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.int32(pos))
        toks.append(int(jnp.argmax(l, -1)[0]))
        pos += 1

    eng = ServingEngine([Replica(model, params, n_slots=2, max_seq=64)])
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    out = eng.run_until_drained()
    assert out[0].tokens == toks


def test_continuous_batching_mixed_lengths(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    eng = ServingEngine([Replica(model, params, n_slots=3, max_seq=96)])
    for i in range(7):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(1, cfg.vocab_size,
                                               int(rng.integers(3, 40))
                                               ).astype(np.int32),
                           max_new_tokens=int(rng.integers(2, 6))))
    done = eng.run_until_drained()
    assert sorted(c.uid for c in done) == list(range(7))


def test_isolated_slots_give_same_output(small_model):
    """A request's output must not depend on its co-batched neighbours."""
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    req = Request(uid=0, prompt=rng.integers(1, cfg.vocab_size, 9
                                             ).astype(np.int32),
                  max_new_tokens=4)
    solo = ServingEngine([Replica(model, params, n_slots=4, max_seq=64)])
    solo.submit(req)
    a = solo.run_until_drained()[0].tokens

    crowd = ServingEngine([Replica(model, params, n_slots=4, max_seq=64)])
    crowd.submit(Request(uid=9, prompt=rng.integers(
        1, cfg.vocab_size, 20).astype(np.int32), max_new_tokens=6))
    crowd.submit(Request(uid=0, prompt=req.prompt, max_new_tokens=4))
    outs = {c.uid: c.tokens for c in crowd.run_until_drained()}
    assert outs[0] == a


def test_ws_balances_across_replicas(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    reps = [Replica(model, params, n_slots=4, max_seq=64) for _ in range(2)]
    eng = ServingEngine(reps, policy="ws")
    for i in range(8):
        eng.submit(Request(uid=i, prompt=rng.integers(
            1, cfg.vocab_size, 10).astype(np.int32), max_new_tokens=3))
    eng._admit_backlog()
    # WS must spread admissions over both replicas
    assert reps[0].queue_len() > 0 and reps[1].queue_len() > 0
    eng.run_until_drained()


def test_sampling_temperature_zero_is_greedy():
    from repro.serve.sampling import sample
    logits = jnp.asarray([[1.0, 5.0, 2.0], [0.0, -1.0, 3.0]])
    toks = sample(logits, jax.random.key(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(toks), [1, 2])


def test_sampling_top_k_restricts_support():
    from repro.serve.sampling import sample
    logits = jnp.asarray([[10.0, 9.0, -50.0, -50.0]])
    for s in range(20):
        t = int(sample(logits, jax.random.key(s), temperature=1.0,
                       top_k=2)[0])
        assert t in (0, 1)
