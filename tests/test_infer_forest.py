"""Packed Forest: oracle equality of every impl + persistence round-trip."""

import numpy as np
import pytest
from conftest import make_tree_dataset

from repro.core import binning, c45
from repro.core.config import GrowConfig
from repro.core.tree import predict as tree_predict, trees_equal
from repro.infer import forest as F
from repro.infer.forest import Forest

IMPLS = ("ref", "vmap", "pallas")


def _bootstrap_trees(ds, rng, n_trees=4, cfg=GrowConfig()):
    return [c45.build(ds.subset(rng.choice(ds.n_cases, ds.n_cases)), cfg)
            for _ in range(n_trees)]


@pytest.fixture
def ds(rng):
    return make_tree_dataset(rng, n=350, unknown_frac=0.15)


class TestPack:
    def test_shapes_and_live_prefixes(self, ds, rng):
        trees = _bootstrap_trees(ds, rng)
        fo = Forest.pack(trees)
        assert fo.n_trees == 4
        assert fo.capacity == max(t.size for t in trees)
        assert [int(n) for n in np.asarray(fo.n_nodes)] \
            == [t.size for t in trees]
        assert fo.n_levels == max(t.depth for t in trees) + 1

    def test_unpack_round_trips_each_tree(self, ds, rng):
        trees = _bootstrap_trees(ds, rng)
        fo = Forest.pack(trees)
        for i, t in enumerate(trees):
            back = fo.tree(i)
            # capacity differs (forest-wide padding); live prefix must match
            assert trees_equal(back, t)
            got = np.asarray(tree_predict(back, ds.x, ds.attr_is_cont))
            want = np.asarray(tree_predict(t, ds.x, ds.attr_is_cont))
            np.testing.assert_array_equal(got, want)

    def test_pack_rejects_mixed_classes_and_bad_weights(self, ds, rng):
        t2 = c45.build(ds, GrowConfig())
        t3 = c45.build(
            binning.fit([np.array([0, 1, 2])], np.array([0, 1, 2]),
                        attr_is_cont=[False], n_classes=3),
            GrowConfig())
        with pytest.raises(ValueError):
            Forest.pack([t2, t3])
        with pytest.raises(ValueError):
            Forest.pack([t2], weights=[1.0, 2.0])
        with pytest.raises(ValueError):
            Forest.pack([])


class TestOracleEquality:
    @pytest.mark.parametrize("impl", IMPLS)
    def test_per_tree_equals_tree_predict(self, ds, rng, impl):
        """Every impl == per-tree core.tree.predict, unknowns included."""
        trees = _bootstrap_trees(ds, rng)
        fo = Forest.pack(trees)
        got = np.asarray(F.predict_per_tree(fo, ds.x, ds.attr_is_cont,
                                            impl=impl))
        want = np.stack([
            np.asarray(tree_predict(t, ds.x, ds.attr_is_cont))
            for t in trees])
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("impl", ("vmap", "pallas"))
    def test_discrete_and_wide_splits(self, rng, impl):
        """Discrete multiway splits and unknown routing survive packing."""
        xs, ys = [], []
        for v in range(11):
            reps = 40 if v == 9 else 4
            xs += [v] * reps
            ys += [1 if v == 9 else v % 2] * reps
        ds = binning.fit([np.array(xs)], np.array(ys),
                         attr_is_cont=[False], n_classes=2)
        tree = c45.build(ds, GrowConfig(min_objs=1.0))
        fo = Forest.pack([tree, tree])
        probe = np.array([[3], [9], [-1]], np.int32)   # known, heavy, unknown
        got = np.asarray(F.predict_per_tree(fo, probe, ds.attr_is_cont,
                                            impl=impl))
        want = np.asarray(tree_predict(tree, probe, ds.attr_is_cont))
        np.testing.assert_array_equal(got[0], want)
        np.testing.assert_array_equal(got[1], want)
        assert got[0][2] == 1              # unknown followed the heavy child

    def test_single_tree_forest_is_identity(self, ds, rng):
        tree = c45.build(ds, GrowConfig())
        fo = Forest.pack([tree])
        for impl in IMPLS:
            got = np.asarray(F.predict(fo, ds.x, ds.attr_is_cont, impl=impl))
            want = np.asarray(tree_predict(tree, ds.x, ds.attr_is_cont))
            np.testing.assert_array_equal(got, want)

    def test_unknown_impl_rejected(self, ds, rng):
        fo = Forest.pack([c45.build(ds, GrowConfig())])
        with pytest.raises(ValueError):
            F.predict_per_tree(fo, ds.x, ds.attr_is_cont, impl="cuda")


class TestVoting:
    def test_weighted_vote_tally(self):
        per_tree = np.array([[0, 1], [0, 1], [1, 0]], np.int32)
        majority = np.asarray(F.vote(per_tree, np.ones(3, np.float32),
                                     n_classes=2))
        np.testing.assert_array_equal(majority, [0, 1])
        # one dominant tree flips the vote
        skewed = np.asarray(F.vote(per_tree,
                                   np.array([1.0, 1.0, 5.0], np.float32),
                                   n_classes=2))
        np.testing.assert_array_equal(skewed, [1, 0])

    def test_ensemble_vote_consistent_across_impls(self, ds, rng):
        trees = _bootstrap_trees(ds, rng, n_trees=5)
        fo = Forest.pack(trees, weights=rng.uniform(0.5, 2.0, 5))
        preds = {impl: np.asarray(F.predict(fo, ds.x, ds.attr_is_cont,
                                            impl=impl))
                 for impl in IMPLS}
        np.testing.assert_array_equal(preds["ref"], preds["vmap"])
        np.testing.assert_array_equal(preds["ref"], preds["pallas"])


class TestPersistenceRoundTrip:
    def test_pack_save_load_predictions_bit_identical(self, ds, rng,
                                                      tmp_path):
        """pack -> publish -> load: predictions == per-tree tree.predict."""
        from repro.infer import registry
        trees = _bootstrap_trees(ds, rng)
        fo = Forest.pack(trees)
        path = registry.publish(str(tmp_path), "m", fo)
        loaded, manifest = registry.load(path)
        assert manifest["n_trees"] == 4
        assert manifest["capacity"] == fo.capacity
        for impl in IMPLS:
            got = np.asarray(F.predict_per_tree(
                loaded, ds.x, ds.attr_is_cont, impl=impl))
            want = np.stack([
                np.asarray(tree_predict(t, ds.x, ds.attr_is_cont))
                for t in trees])
            np.testing.assert_array_equal(got, want)
