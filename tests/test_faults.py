"""Supervised farm fault paths: crash/retry/quarantine, deadlines, deaths.

Every test that exercises a termination guarantee runs under
``run_with_timeout`` so a supervision regression *fails* instead of hanging
the suite (the pre-supervision farm deadlocked forever on a single worker
exception).  ``pytest.mark.timeout`` is applied as a second backstop for
environments with pytest-timeout installed.
"""

import threading
import time

import pytest

from conftest import run_with_timeout
from repro.core import faults
from repro.core.farm import (AllWorkersDead, Farm, FaultPolicy, TaskFailure,
                             WorkerCrashed)
from repro.core.scheduler import OD, WS, HealthWS, QueueState
from repro.train.elastic import FarmHealth, HeartbeatMonitor, StragglerMonitor

pytestmark = pytest.mark.timeout(120)


def range_emitter(n):
    """Emitter that floods n tasks at start-up and collects results."""
    seen = []

    def emitter(task, send):
        if task is None:
            for i in range(n):
                send(i, weight=float(i + 1))
        else:
            seen.append(task)
    return emitter, seen


def results(seen):
    return sorted(x for x in seen if not isinstance(x, TaskFailure))


# ---------------------------------------------------------------------------
# deadlock regressions (satellite: the original farm hung on any exception)
# ---------------------------------------------------------------------------

def test_worker_exception_does_not_deadlock_run():
    """A crashing worker_svc must terminate the run, not hang feedback.get."""
    emitter, seen = range_emitter(10)

    def svc(x):
        if x == 4:
            raise ValueError("boom")
        return x

    farm = Farm(3, fault=FaultPolicy(max_retries=1, backoff_base=0.0))
    stats = run_with_timeout(lambda: farm.run(emitter, svc), 30)
    assert results(seen) == [x for x in range(10) if x != 4]
    assert stats["quarantined"] == 1
    assert stats["failures"] == 2          # initial attempt + 1 retry
    assert farm.quarantined[0].payload == 4


def test_send_out_aborts_when_all_workers_dead():
    """The full-queue spin in send_out must raise, not spin forever."""
    def svc(x):
        raise WorkerCrashed("gone")

    def emitter(task, send):
        if task is None:
            for i in range(10):
                send(i)

    farm = Farm(1, policy=OD(), fault=FaultPolicy(max_retries=3))
    with pytest.raises(AllWorkersDead):
        run_with_timeout(lambda: farm.run(emitter, svc), 30)


def test_zero_live_workers_raises_with_tasks_outstanding():
    emitter, _ = range_emitter(5)
    farm = Farm(2, fault=FaultPolicy(max_retries=4))
    with pytest.raises(AllWorkersDead):
        run_with_timeout(
            lambda: farm.run(emitter, lambda x: (_ for _ in ()).throw(
                WorkerCrashed("dead"))), 30)


# ---------------------------------------------------------------------------
# retry / backoff / quarantine
# ---------------------------------------------------------------------------

def test_retry_recovers_transient_crashes():
    attempts = {}
    lock = threading.Lock()

    def svc(x):
        with lock:
            attempts[x] = attempts.get(x, 0) + 1
            if attempts[x] == 1 and x % 3 == 0:
                raise RuntimeError(f"transient {x}")
        return x

    emitter, seen = range_emitter(12)
    farm = Farm(4, fault=FaultPolicy(max_retries=2, backoff_base=1e-4))
    stats = run_with_timeout(lambda: farm.run(emitter, svc), 30)
    assert results(seen) == list(range(12))
    assert stats["retries"] == 4           # 0, 3, 6, 9
    assert stats["quarantined"] == 0


def test_quarantine_after_budget_and_emitter_notified():
    emitter_fail = []

    def emitter(task, send):
        if task is None:
            send("poison")
            send("fine")
        elif isinstance(task, TaskFailure):
            emitter_fail.append(task)

    def svc(x):
        if x == "poison":
            raise RuntimeError("always")
        return x

    farm = Farm(2, fault=FaultPolicy(max_retries=2, quarantine_after=2,
                                     backoff_base=0.0))
    stats = run_with_timeout(lambda: farm.run(emitter, svc), 30)
    assert stats["quarantined"] == 1
    assert stats["failures"] == 2          # quarantine_after overrides
    assert emitter_fail[0].payload == "poison"
    assert "always" in emitter_fail[0].error


def test_backoff_is_bounded_and_jittered():
    import random
    pol = FaultPolicy(backoff_base=0.01, backoff_factor=2.0, backoff_max=0.05,
                      jitter=0.5)
    rng = random.Random(0)
    delays = [pol.backoff(k, rng) for k in range(1, 12)]
    assert all(0 < d <= 0.05 * 1.5 for d in delays)
    assert delays[1] != delays[2]          # jitter decorrelates
    assert FaultPolicy(backoff_base=0.0).backoff(3, rng) == 0.0


# ---------------------------------------------------------------------------
# deadlines (hung workers) and worker death
# ---------------------------------------------------------------------------

def test_deadline_declares_hung_worker_dead_and_redispatches():
    hung = threading.Event()

    def svc(x):
        if x == 5 and not hung.is_set():
            hung.set()
            time.sleep(3.0)                # >> deadline
        return x * 10

    emitter, seen = range_emitter(8)
    farm = Farm(3, fault=FaultPolicy(task_deadline=0.25, max_retries=3,
                                     backoff_base=1e-4))
    stats = run_with_timeout(lambda: farm.run(emitter, svc), 30)
    assert results(seen) == [x * 10 for x in range(8)]
    assert stats["timeouts"] >= 1
    assert len(stats["dead_workers"]) == 1


def test_worker_death_requeues_its_backlog():
    inj = faults.FaultInjector(seed=0, spec=faults.FaultSpec(
        dead_workers=frozenset({0})))
    emitter, seen = range_emitter(30)
    farm = Farm(3, fault=FaultPolicy(max_retries=2))
    stats = run_with_timeout(
        lambda: farm.run(emitter, inj.wrap_worker(lambda x: x)), 30)
    assert results(seen) == list(range(30))
    assert stats["dead_workers"] == [0]
    assert stats["n_live_workers"] == 2


def test_stats_expose_failure_breakdown():
    emitter, _ = range_emitter(4)
    farm = Farm(2)
    stats = run_with_timeout(lambda: farm.run(emitter, lambda x: x), 30)
    for key in ("failures", "retries", "requeues", "timeouts", "quarantined",
                "dead_workers", "n_live_workers", "emitter_busy",
                "worker_busy", "worker_tasks"):
        assert key in stats
    assert stats["failures"] == 0
    assert sum(stats["worker_tasks"]) == 4


# ---------------------------------------------------------------------------
# deterministic injection harness
# ---------------------------------------------------------------------------

def test_injector_is_deterministic_across_runs():
    spec = faults.FaultSpec(crash_p=0.3, die_p=0.1, hang_p=0.05, slow_p=0.2)
    a = faults.FaultInjector(seed=42, spec=spec)
    b = faults.FaultInjector(seed=42, spec=spec)
    keys = [(k, c) for k in range(50) for c in range(3)]
    assert [a.decide(k, c) for k, c in keys] == \
        [b.decide(k, c) for k, c in keys]
    c = faults.FaultInjector(seed=43, spec=spec)
    assert [a.decide(k, c_) for k, c_ in keys] != \
        [c.decide(k, c_) for k, c_ in keys]


def test_injector_rates_roughly_match_probabilities():
    spec = faults.FaultSpec(crash_p=0.25)
    inj = faults.FaultInjector(seed=1, spec=spec)
    n = 2000
    crashes = sum(inj.decide(k, 0) == "crash" for k in range(n))
    assert 0.18 < crashes / n < 0.32


def test_injector_probabilities_must_be_sane():
    with pytest.raises(ValueError):
        faults.FaultSpec(crash_p=0.7, hang_p=0.5)


# ---------------------------------------------------------------------------
# elastic wiring: heartbeat + straggler weights into the scheduling path
# ---------------------------------------------------------------------------

def test_health_ws_biases_away_from_stragglers():
    health = FarmHealth(2)
    for _ in range(8):
        health.on_task(0, 1.0)    # w0: slow
        health.on_task(1, 0.1)    # w1: fast
    pol = health.policy()
    views = [QueueState(tasks=0, weight=1.0, cap=8),
             QueueState(tasks=0, weight=2.0, cap=8)]
    # plain WS would pick 0 (lower raw weight); health-WS picks the fast one
    assert WS().pick(1.0, views) == 0
    assert pol.pick(1.0, views) == 1


def test_health_ws_skips_dead_and_heartbeat_failed_workers():
    hb = HeartbeatMonitor(timeout=10.0)
    health = FarmHealth(3, heartbeat=hb)
    health.on_task(0, 0.1, now=0.0)
    health.on_task(1, 0.1, now=100.0)      # w0 is now 100s silent -> failed
    health.on_worker_dead(2)
    speeds = health.speeds(now=100.0)
    assert speeds[0] == 0.0 and speeds[2] == 0.0 and speeds[1] > 0
    pol = HealthWS(lambda: speeds)
    views = [QueueState(0, 0.0, 8), QueueState(5, 50.0, 8),
             QueueState(0, 0.0, 8)]
    assert pol.pick(1.0, views) == 1       # only healthy candidate wins
    # ...but if every healthy queue is full, fall back to raw WS capacity
    views_full = [QueueState(0, 0.0, 8), QueueState(8, 50.0, 8),
                  QueueState(0, 0.0, 8)]
    assert pol.pick(1.0, views_full) in (0, 2)


def test_farm_feeds_health_monitors():
    health = FarmHealth(2)
    emitter, seen = range_emitter(10)
    farm = Farm(2, health=health)
    run_with_timeout(lambda: farm.run(emitter, lambda x: x), 30)
    assert isinstance(farm.policy, HealthWS)
    assert results(seen) == list(range(10))
    assert set(health.straggler.times) <= {"w0", "w1"}
    assert len(health.heartbeat.hosts) >= 1


def test_farm_reports_dead_worker_to_health():
    health = FarmHealth(2)
    inj = faults.FaultInjector(seed=0, spec=faults.FaultSpec(
        dead_workers=frozenset({1})))
    emitter, seen = range_emitter(12)
    farm = Farm(2, health=health, fault=FaultPolicy(max_retries=2))
    run_with_timeout(
        lambda: farm.run(emitter, inj.wrap_worker(lambda x: x)), 30)
    assert health.dead == {1}
    assert health.speeds()[1] == 0.0
    assert results(seen) == list(range(12))
