"""Report rendering + QUEST classification-function coverage."""

import json

import numpy as np
import pytest

from repro.data import quest
from repro.launch import report, roofline as rl


@pytest.mark.parametrize("fn", [1, 2, 3, 4, 5])
def test_quest_functions_produce_both_classes(fn):
    ds = quest.generate(2_000, function=fn, seed=0, perturbation=0.0)
    frac = ds.y.mean()
    assert 0.02 < frac < 0.98, f"function {fn} degenerate: {frac}"


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%p), replica_groups=[16,16]<=[256]
  %ar = f32[64]{0} all-reduce(%x), replica_groups=[1,256]<=[256]
  %rs = f32[4,8]{1,0} reduce-scatter(%y), replica_groups=[16,16]<=[256]
  %cp = f32[10]{0} collective-permute(%z), channels=...
  %other = f32[99]{0} add(%a, %b)
"""
    total, by_op = rl.collective_bytes(hlo, n_devices=256)
    ag = 16 * 1024 * 2 * (15 / 16)
    ar = 64 * 4 * 2 * (255 / 256)
    rs = 4 * 8 * 4 * 15
    cp = 10 * 4
    assert by_op["all-gather"] == pytest.approx(ag)
    assert by_op["all-reduce"] == pytest.approx(ar)
    assert by_op["reduce-scatter"] == pytest.approx(rs)
    assert by_op["collective-permute"] == pytest.approx(cp)
    assert total == pytest.approx(ag + ar + rs + cp)


def test_report_renders_mixed_results(tmp_path):
    data = {
        "a/train_4k": dict(status="ok", arch="a", shape="train_4k",
                           t_compute=0.01, t_memory=0.02, t_collective=0.005,
                           bottleneck="memory", useful_flops_ratio=0.5,
                           mem_temp_gb=3.2),
        "b/decode_32k": dict(status="fail", error="Boom"),
        "c/prefill_32k": dict(status="ok", mem_temp_gb=1.0),
    }
    p = tmp_path / "r.json"
    p.write_text(json.dumps(data))
    table = report.render(str(p))
    assert "| a | train_4k | 10.0 | 20.0 | 5.0 | memory | 0.50 | 3.2 |" in table
    assert "FAIL" in table and "compile-only" in table
    assert "1/3" not in report.summarize(str(p))  # 2/3 ok


def test_model_flops_formulas():
    t = rl.model_flops_for("yi_6b", "train_4k")
    from repro.configs import base as cfgbase
    n = cfgbase.get_config("yi_6b").param_count()
    assert t == pytest.approx(6.0 * n * 256 * 4096)
    d = rl.model_flops_for("yi_6b", "decode_32k")
    assert d == pytest.approx(2.0 * n * 128)
    moe_t = rl.model_flops_for("phi35_moe", "train_4k")
    cfg = cfgbase.get_config("phi35_moe")
    assert moe_t == pytest.approx(6.0 * cfg.active_param_count() * 256 * 4096)
