"""Unit + property tests for the shared C4.5 scoring math."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import entropy


def test_info_closed_forms():
    assert float(entropy.info(jnp.array([5.0, 5.0]))) == pytest.approx(1.0)
    assert float(entropy.info(jnp.array([8.0, 0.0]))) == pytest.approx(0.0)
    assert float(entropy.info(jnp.array([2.0, 2.0, 2.0, 2.0]))
                 ) == pytest.approx(2.0)
    assert float(entropy.info(jnp.array([0.0, 0.0]))) == 0.0


def test_gain_perfect_split():
    # children perfectly pure: gain == parent entropy
    children = jnp.array([[6.0, 0.0], [0.0, 6.0]])
    g = entropy.split_gain_from_children(children)
    assert float(g) == pytest.approx(1.0, abs=1e-6)


def test_gain_useless_split():
    children = jnp.array([[3.0, 3.0], [3.0, 3.0]])
    assert float(entropy.split_gain_from_children(children)) == pytest.approx(
        0.0, abs=1e-6)


def test_unknown_fraction_scaling():
    children = jnp.array([[6.0, 0.0], [0.0, 6.0]])
    g_all = entropy.split_gain_from_children(children,
                                             total_w=jnp.float32(12.0))
    g_half = entropy.split_gain_from_children(children,
                                              total_w=jnp.float32(24.0))
    assert float(g_half) == pytest.approx(float(g_all) / 2, rel=1e-5)


def test_continuous_best_threshold():
    # classes split exactly at bin 1|2
    hist = jnp.zeros((4, 2)).at[0, 0].set(3).at[1, 0].set(3) \
        .at[2, 1].set(3).at[3, 1].set(3)
    score, bin_ = entropy.gains_for_continuous(
        hist, total_w=jnp.float32(12.0), n_bins=jnp.int32(4))
    assert int(bin_) == 1
    assert float(score) == pytest.approx(1.0, abs=1e-5)


def test_min_objs_validity():
    hist = jnp.zeros((3, 2)).at[0, 0].set(1).at[1, 1].set(50) \
        .at[2, 1].set(50)
    score, _ = entropy.gains_for_continuous(
        hist, total_w=jnp.float32(101.0), n_bins=jnp.int32(3), min_objs=2.0)
    # the only informative cut (after bin 0) leaves 1 < min_objs on the left
    # but cut after bin 1 is valid (51 | 50) with ~0 gain
    assert np.isfinite(float(score))


def test_discrete_needs_two_branches():
    hist = jnp.zeros((3, 2)).at[0, 0].set(10.0)       # all in one value
    s = entropy.gains_for_discrete(hist, total_w=jnp.float32(10.0),
                                   n_bins=jnp.int32(3))
    assert float(s) == -np.inf


def test_pick_best_attribute_first_max_and_active_mask():
    score = jnp.array([[0.5, 0.9, 0.9, 0.2]])
    active = jnp.array([[True, True, True, True]])
    a, s, ok = entropy.pick_best_attribute(score, active)
    assert int(a[0]) == 1 and bool(ok[0])
    active = jnp.array([[True, False, False, True]])
    a, s, ok = entropy.pick_best_attribute(score, active)
    assert int(a[0]) == 0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0, 100), min_size=2, max_size=6))
def test_info_bounds(counts):
    c = jnp.array(counts, jnp.float32)
    h = float(entropy.info(c))
    assert 0.0 <= h <= np.log2(len(counts)) + 1e-4


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 5), st.integers(2, 6), st.data())
def test_gain_nonnegative_and_leq_parent_entropy(nc, nh, data):
    rows = data.draw(st.lists(
        st.lists(st.floats(0, 50), min_size=nc, max_size=nc),
        min_size=nh, max_size=nh))
    children = jnp.array(rows, jnp.float32)
    parent = jnp.sum(children, axis=0)
    g = float(entropy.split_gain_from_children(children))
    assert g >= -1e-4
    assert g <= float(entropy.info(parent)) + 1e-3


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_gain_permutation_invariance(data):
    nh = data.draw(st.integers(2, 5))
    rows = data.draw(st.lists(
        st.lists(st.floats(0, 20), min_size=3, max_size=3),
        min_size=nh, max_size=nh))
    children = jnp.array(rows, jnp.float32)
    perm = data.draw(st.permutations(range(nh)))
    g1 = float(entropy.split_gain_from_children(children))
    g2 = float(entropy.split_gain_from_children(children[jnp.array(perm)]))
    assert g1 == pytest.approx(g2, abs=1e-5)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_fayyad_irani_mask_preserves_best_gain(data):
    """Masking non-boundary cuts never changes the best achievable gain."""
    b = data.draw(st.integers(3, 12))
    c = data.draw(st.integers(2, 4))
    rows = data.draw(st.lists(
        st.lists(st.integers(0, 6), min_size=c, max_size=c),
        min_size=b, max_size=b))
    hist = jnp.array(rows, jnp.float32)
    # sparsify some bins so empty-run handling is exercised
    kill = data.draw(st.lists(st.integers(0, b - 1), max_size=3))
    for k in kill:
        hist = hist.at[k].set(0.0)
    total = float(hist.sum())
    score, _ = entropy.gains_for_continuous(
        hist, total_w=jnp.float32(total), n_bins=jnp.int32(b), min_objs=0.0)
    mask = entropy.fayyad_irani_mask(hist)
    masked = jnp.where(mask, 0.0, -jnp.inf)
    # recompute candidate gains and apply the mask
    left = jnp.cumsum(hist, axis=0)
    known = left[-1]
    right = known[None] - left
    safe_w = max(float(known.sum()), 1e-7)
    gain = (entropy.weighted_info(known)
            - entropy.weighted_info(left) - entropy.weighted_info(right)
            ) / safe_w
    structural = jnp.arange(b) < b - 1
    g_all = jnp.where(structural, gain, -jnp.inf)
    g_fi = jnp.where(structural & mask, gain, -jnp.inf)
    best_all = float(jnp.max(g_all))
    best_fi = float(jnp.max(g_fi))
    # F&I guarantees boundary points achieve the max only when a positive-
    # gain split exists; at zero gain every cut may be masked (C4.5 makes a
    # leaf there regardless — see entropy.EPS_GAIN in pick_best_attribute).
    if np.isfinite(best_all) and best_all > 1e-5:
        assert best_fi == pytest.approx(best_all, abs=2e-5), (
            np.asarray(hist).tolist())
