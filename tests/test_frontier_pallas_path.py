"""impl="pallas" must exercise BOTH kernels and still equal the oracle.

Spies on the :mod:`repro.kernels.ops` entry points (the only route from the
frontier engine to the Pallas kernels) prove the histogram *and* the fused
split-gain kernel are actually on the hot path — a regression here silently
reverts splitAtt to the jnp reference and nobody notices until a profile.
"""

import jax
import numpy as np
import pytest

from repro.core import c45, frontier
from repro.core.config import GrowConfig
from repro.core.tree import predict, trees_equal
from repro.data import datasets
from repro.kernels import compaction, ops


@pytest.fixture
def kernel_spies(monkeypatch):
    calls = {"histogram": 0, "split_gain": 0}
    real_hist, real_gain = ops.frontier_histogram, ops.split_gain

    def spy_hist(*a, **kw):
        calls["histogram"] += 1
        return real_hist(*a, **kw)

    def spy_gain(*a, **kw):
        calls["split_gain"] += 1
        return real_gain(*a, **kw)

    monkeypatch.setattr(ops, "frontier_histogram", spy_hist)
    monkeypatch.setattr(ops, "split_gain", spy_gain)
    # the build jit is cached per (prob, impl); force a retrace so the spies
    # observe the kernel calls of *this* test
    jax.clear_caches()
    return calls


# Table-1 stand-ins at CPU scale: one wide-schema (40 attrs, discrete-heavy)
# and one QUEST-generated (9 attrs, continuous-heavy, 10M-case original).
BUNDLED = [("census_pums", 0.001), ("syd10m9a", 0.00002)]


@pytest.mark.parametrize("name,scale", BUNDLED)
def test_pallas_path_uses_both_kernels_and_matches_oracle(
        name, scale, kernel_spies):
    ds = datasets.load(name, scale=scale, max_bins=16)
    cfg = GrowConfig(max_nodes=4096, frontier_slots=32,
                     compact_min_bucket=64)
    t_pal = frontier.build(ds, cfg, impl="pallas")

    assert kernel_spies["histogram"] >= 1, "histogram kernel not on hot path"
    assert kernel_spies["split_gain"] >= 1, "split_gain kernel not on hot path"
    # with N > min_bucket the compaction ladder has several buckets, and the
    # switch traces the histogram kernel once per bucket
    n_buckets = len(compaction.bucket_sizes(ds.n_cases, min_bucket=64))
    assert n_buckets > 1
    assert kernel_spies["histogram"] >= n_buckets

    t_jnp = frontier.build(ds, cfg, impl="jnp")
    t_seq = c45.build(ds, cfg, capacity=cfg.max_nodes)
    assert trees_equal(t_seq, t_pal), "pallas tree != sequential oracle"
    assert trees_equal(t_jnp, t_pal), "pallas tree != jnp tree"
    p_seq = np.asarray(predict(t_seq, ds.x, ds.attr_is_cont))
    p_pal = np.asarray(predict(t_pal, ds.x, ds.attr_is_cont))
    assert (p_seq == p_pal).all()


def test_pallas_no_compact_also_matches(kernel_spies):
    ds = datasets.load("census_pums", scale=0.001, max_bins=16)
    cfg = GrowConfig(max_nodes=4096, frontier_slots=32, compact=False)
    t_pal = frontier.build(ds, cfg, impl="pallas")
    assert kernel_spies["histogram"] == kernel_spies["split_gain"] == 1
    t_seq = c45.build(ds, cfg, capacity=cfg.max_nodes)
    assert trees_equal(t_seq, t_pal)


def test_split_gain_scores_match_jnp_scoring():
    """The kernel's (K, A) planes vs entropy.gains_from_histogram: identical
    split decisions (exact bins), scores equal to FP noise (<= a few ULP —
    the kernel body runs the same entropy ops, but compiled per VMEM block,
    so reduction association can differ at the 1e-8 level)."""
    import jax.numpy as jnp
    from repro.core import entropy

    rng = np.random.default_rng(11)
    for k, a, b, c in [(8, 8, 8, 5), (5, 9, 13, 3), (16, 3, 32, 2)]:
        hist = (rng.uniform(0, 8, (k, a, b, c))
                * (rng.random((k, a, b, c)) < .7)).astype(np.float32)
        tw = hist.sum((1, 2, 3)).astype(np.float32) / a
        cont = rng.random(a) < .5
        nb = rng.integers(2, b + 1, a).astype(np.int32)
        for crit in ("gain", "gain_ratio"):
            s_ref, b_ref = entropy.gains_from_histogram(
                jnp.asarray(hist), total_w=jnp.asarray(tw),
                attr_is_cont=jnp.asarray(cont), n_bins=jnp.asarray(nb),
                criterion=crit)
            s_ker, b_ker = ops.split_gain(hist, tw, cont, nb, criterion=crit)
            np.testing.assert_array_equal(np.asarray(b_ref),
                                          np.asarray(b_ker))
            np.testing.assert_allclose(np.asarray(s_ker),
                                       np.asarray(s_ref),
                                       rtol=1e-6, atol=1e-6)
