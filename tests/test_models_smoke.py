"""Per-architecture smoke tests: reduced config, forward + train step on CPU,
output shapes, no NaNs (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models.frontends import fake_frontend_embeds
from repro.models.model import build_model

LM_ARCHS = [a for a in cfgbase.ARCH_IDS if a != "yadt"]


def _batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                           jnp.int32),
        labels=jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                           jnp.int32))
    fe = fake_frontend_embeds(cfg, b)
    if fe is not None:
        batch["frontend_embeds"] = fe
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_loss(arch):
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: model.loss_fn(p, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < 1.5 * np.log(cfg.vocab_size)
    assert float(metrics["n_tokens"]) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_reduces_loss(arch):
    from repro.train import optimizer as opt
    from repro.train.train_step import init_state, make_train_step
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    model = build_model(cfg)
    state = init_state(model.init(jax.random.key(0)))
    step = jax.jit(make_train_step(
        lambda p, b: model.loss_fn(p, b),
        opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)))
    batch = _batch(cfg)
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)   # same batch: loss must drop
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ["gemma2_9b", "rwkv6_3b",
                                  "recurrentgemma_2b", "musicgen_medium"])
def test_decode_matches_prefill(arch):
    """Serving path consistency for each block-kind family (dense local/
    global+softcap, rwkv, rglru hybrid, MHA/layernorm/sinusoidal)."""
    cfg = cfgbase.reduced(cfgbase.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 48
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, t, max_seq=s + 4))(params, toks)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    dec, _ = jax.jit(model.decode_step)(params, cache, nxt, jnp.int32(s))
    ref, _ = jax.jit(lambda p, t: model.prefill(p, t, max_seq=s + 4))(
        params, jnp.concatenate([toks, nxt], axis=1))
    diff = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                 - ref.astype(jnp.float32))))
    assert diff < 0.15, f"decode/prefill mismatch {diff}"


def test_moe_routes_and_balances():
    from repro.models import moe
    from repro.models.transformer import moe_spec
    cfg = cfgbase.reduced(cfgbase.get_config("phi35_moe"))
    spec = moe_spec(cfg)
    p = moe.moe_init(jax.random.key(0), spec)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 32, cfg.d_model)),
                    jnp.bfloat16)
    out, stats = moe.moe_apply(p, x, spec)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert float(stats["moe_aux"]) > 0.0


def test_param_count_sane():
    # full configs: analytic parameter counts in the expected ballparks
    expected = {"phi35_moe": (35e9, 50e9), "llama4_scout": (90e9, 130e9),
                "llava_next_34b": (30e9, 40e9), "yi_6b": (5e9, 7e9),
                "gemma2_9b": (8e9, 12e9), "phi4_mini": (3e9, 5e9),
                "rwkv6_3b": (2.5e9, 4e9), "recurrentgemma_2b": (2e9, 4e9),
                "musicgen_medium": (1e9, 2.5e9), "gemma3_4b": (3e9, 6e9)}
    for arch, (lo, hi) in expected.items():
        n = cfgbase.get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_less_than_total():
    cfg = cfgbase.get_config("phi35_moe")
    assert cfg.active_param_count() < cfg.param_count() / 4


def test_runnable_shapes_skips():
    long_runners = {a for a in LM_ARCHS
                    if any(s.name == "long_500k" for s in
                           cfgbase.runnable_shapes(cfgbase.get_config(a)))}
    assert long_runners == {"rwkv6_3b", "gemma3_4b", "gemma2_9b",
                            "recurrentgemma_2b"}
