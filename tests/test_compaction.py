"""Active-case compaction + block autotune: invariants and equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, compaction, ops, ref


def test_bucket_ladder_shape():
    assert compaction.bucket_sizes(500, min_bucket=1024) == (500,)
    assert compaction.bucket_sizes(1024, min_bucket=1024) == (1024,)
    assert compaction.bucket_sizes(3000, min_bucket=1024) == (1024, 2048, 3000)
    assert compaction.bucket_sizes(4096, min_bucket=512) == (512, 1024, 2048,
                                                             4096)
    ladder = compaction.bucket_sizes(100_000, min_bucket=1024)
    assert ladder[-1] == 100_000 and all(
        b == 1024 << i for i, b in enumerate(ladder[:-1]))


def _problem(rng, n, a, b, c, k, frac_active):
    x = rng.integers(-1, b, (n, a)).astype(np.int32)
    y = rng.integers(0, c, n).astype(np.int32)
    w = rng.uniform(0.1, 2.0, n).astype(np.float32)
    slot = rng.integers(0, k, n).astype(np.int32)
    slot[rng.random(n) >= frac_active] = -1
    return x, y, w, slot


@pytest.mark.parametrize("frac_active", [0.0, 0.03, 0.5, 1.0])
def test_compact_matches_full(frac_active):
    """Bucketed gather == full-N kernel == jnp reference, any liveness."""
    rng = np.random.default_rng(int(frac_active * 100))
    n, a, b, c, k = 700, 3, 9, 4, 6
    x, y, w, slot = _problem(rng, n, a, b, c, k, frac_active)
    kw = dict(n_slots=k, n_bins=b, n_classes=c)
    want = np.asarray(ref.frontier_histogram_ref(x, y, w, slot, **kw))
    got = np.asarray(ops.frontier_histogram_compact(
        x, y, w, slot, min_bucket=64, **kw))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-5)


def test_compact_under_jit_selects_buckets():
    """The lax.switch ladder is jit-safe and every bucket agrees."""
    rng = np.random.default_rng(3)
    n, a, b, c, k = 512, 2, 5, 3, 4
    kw = dict(n_slots=k, n_bins=b, n_classes=c, min_bucket=32)

    @jax.jit
    def go(x, y, w, slot):
        return ops.frontier_histogram_compact(x, y, w, slot, **kw)

    for n_live in (0, 1, 31, 32, 33, 200, 512):
        x, y, w, slot = _problem(rng, n, a, b, c, k, 1.0)
        slot[n_live:] = -1
        want = np.asarray(ref.frontier_histogram_ref(
            x, y, w, slot, n_slots=k, n_bins=b, n_classes=c))
        np.testing.assert_allclose(np.asarray(go(x, y, w, slot)), want,
                                   atol=1e-4, rtol=1e-5, err_msg=str(n_live))


def test_compact_gather_is_dense():
    """Live rows land contiguously; padding rows carry slot -1 (masked)."""
    slot = jnp.array([-1, 2, -1, 0, -1, -1, 1, -1], jnp.int32)
    part = slot >= 0
    idx = jnp.nonzero(part, size=4, fill_value=0)[0]
    live = jnp.arange(4) < jnp.sum(part.astype(jnp.int32))
    assert np.asarray(idx).tolist() == [1, 3, 6, 0]        # last is filler
    assert np.asarray(
        jnp.where(live, slot[idx], -1)).tolist() == [2, 0, 1, -1]


def test_autotune_respects_budget_and_extents():
    for n, k, b, c, a in [(100, 4, 3, 2, 2), (1 << 20, 256, 128, 23, 41),
                          (50_000, 64, 64, 7, 54)]:
        p = autotune.plan_blocks(n_cases=n, n_slots=k, n_bins=b,
                                 n_classes=c, n_attrs=a)
        # the one-hot expansion + out window must fit the budget
        hist_bytes = 4 * (p.block_t * p.block_k * p.block_b
                          + p.block_k * p.block_b * c)
        gain_bytes = 16 * p.block_k * p.block_a * b * c
        assert hist_bytes <= autotune.VMEM_BUDGET, (n, k, b, c, a)
        assert gain_bytes <= autotune.VMEM_BUDGET, (n, k, b, c, a)
        for v in (p.block_t, p.block_k, p.block_b, p.block_a):
            assert v >= 1 and v & (v - 1) == 0          # power of two


def test_autotune_overrides_win():
    p = autotune.plan_blocks(n_cases=10_000, n_slots=64, n_bins=32,
                             n_classes=4, n_attrs=9,
                             block_t=128, block_k=2, block_b=16, block_a=4)
    assert (p.block_t, p.block_k, p.block_b, p.block_a) == (128, 2, 16, 4)


def test_plan_for_config_reads_grow_config():
    from repro.core.config import GrowConfig
    cfg = GrowConfig(frontier_slots=32, block_k=4)
    p = autotune.plan_for_config(cfg, n_cases=5000, n_bins=16, n_classes=3,
                                 n_attrs=7)
    assert p.block_k == 4
    assert p.block_b == 32          # padded bin axis (16+1 -> 32) fits whole
