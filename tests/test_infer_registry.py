"""Model registry: atomic publish, fault-injected crashes, hot-swap/canary."""

import os

import numpy as np
import pytest
from conftest import make_tree_dataset

from repro.core import c45
from repro.core.config import GrowConfig
from repro.infer import forest as F
from repro.infer import registry
from repro.infer.forest import Forest


@pytest.fixture
def ds(rng):
    return make_tree_dataset(rng, n=250)


@pytest.fixture
def fo(ds, rng):
    trees = [c45.build(ds.subset(rng.choice(ds.n_cases, ds.n_cases)),
                       GrowConfig()) for _ in range(2)]
    return Forest.pack(trees)


def test_publish_versions_monotonically(tmp_path, fo):
    p1 = registry.publish(str(tmp_path), "m", fo)
    p2 = registry.publish(str(tmp_path), "m", fo)
    assert p1.endswith("v00000001") and p2.endswith("v00000002")
    assert registry.latest_valid(str(tmp_path), "m") == p2
    assert [os.path.basename(v)
            for v in registry.list_versions(str(tmp_path), "m")] \
        == ["v00000001", "v00000002"]


def test_publish_accepts_bare_tree(tmp_path, ds):
    tree = c45.build(ds, GrowConfig())
    path = registry.publish(str(tmp_path), "m", tree)
    loaded, manifest = registry.load(path)
    assert manifest["n_trees"] == 1
    got = np.asarray(F.predict(loaded, ds.x, ds.attr_is_cont))
    from repro.core.tree import predict
    np.testing.assert_array_equal(
        got, np.asarray(predict(tree, ds.x, ds.attr_is_cont)))


def test_crash_between_tmp_write_and_rename(tmp_path, fo, monkeypatch):
    """The acceptance fault: a publisher dying after staging but before the
    atomic rename must leave latest_valid() serving the prior version."""
    v1 = registry.publish(str(tmp_path), "m", fo)

    real_replace = os.replace

    def crash(src, dst):
        raise RuntimeError("injected: killed before rename")

    monkeypatch.setattr(registry.os, "replace", crash)
    with pytest.raises(RuntimeError, match="injected"):
        registry.publish(str(tmp_path), "m", fo)
    monkeypatch.setattr(registry.os, "replace", real_replace)

    # the torn tmp.* staging dir exists, but readers never see it
    leftovers = [d for d in os.listdir(tmp_path / "m")
                 if d.startswith("tmp.")]
    assert leftovers
    assert registry.latest_valid(str(tmp_path), "m") == v1
    handle = registry.ModelHandle(str(tmp_path), "m")
    assert handle.stable_path == v1

    # once stale, the torn staging dir is garbage-collected
    stale = tmp_path / "m" / leftovers[0]
    os.utime(stale, (1.0, 1.0))
    registry.latest_valid(str(tmp_path), "m")
    assert not stale.exists()


def test_corrupt_newest_falls_back(tmp_path, fo):
    registry.publish(str(tmp_path), "m", fo)
    v2 = registry.publish(str(tmp_path), "m", fo)
    with open(os.path.join(v2, "model.npz"), "r+b") as f:
        f.seek(-8, 2)
        f.write(b"\xff" * 8)
    assert not registry.verify(v2)
    assert registry.latest_valid(str(tmp_path), "m").endswith("v00000001")


def test_handle_hot_swap(tmp_path, fo):
    registry.publish(str(tmp_path), "m", fo)
    handle = registry.ModelHandle(str(tmp_path), "m")
    assert not handle.refresh()            # nothing newer yet
    v2 = registry.publish(str(tmp_path), "m", fo)
    assert handle.refresh()                # swapped in place
    assert handle.stable_path == v2
    assert not handle.refresh()


def test_handle_requires_published_model(tmp_path):
    with pytest.raises(FileNotFoundError):
        registry.ModelHandle(str(tmp_path), "ghost")


class TestCanaryRouting:
    def test_fraction_is_deterministic_and_close(self, tmp_path, fo):
        registry.publish(str(tmp_path), "m", fo)
        v2 = registry.publish(str(tmp_path), "m", fo)
        handle = registry.ModelHandle(str(tmp_path), "m")
        handle.set_canary(v2, 0.25)
        arms = [handle.route(uid) for uid in range(4000)]
        again = [handle.route(uid) for uid in range(4000)]
        assert arms == again               # same uid -> same arm, always
        frac = arms.count("canary") / len(arms)
        assert 0.2 < frac < 0.3
        handle.clear_canary()
        assert all(handle.route(u) == "stable" for u in range(100))

    def test_shadow_never_shifts_traffic(self, tmp_path, fo):
        registry.publish(str(tmp_path), "m", fo)
        v2 = registry.publish(str(tmp_path), "m", fo)
        handle = registry.ModelHandle(str(tmp_path), "m")
        handle.set_canary(v2, 0.5, shadow=True)
        assert all(handle.route(u) == "stable" for u in range(500))
        assert handle.shadow_model() is not None

    def test_promote_canary(self, tmp_path, fo):
        registry.publish(str(tmp_path), "m", fo)
        v2 = registry.publish(str(tmp_path), "m", fo)
        handle = registry.ModelHandle(str(tmp_path), "m")
        handle.set_canary(v2, 0.1)
        handle.promote_canary()
        assert handle.stable_path == v2
        assert handle.canary is None
        with pytest.raises(ValueError):
            handle.promote_canary()

    def test_canary_must_verify(self, tmp_path, fo):
        registry.publish(str(tmp_path), "m", fo)
        v2 = registry.publish(str(tmp_path), "m", fo)
        with open(os.path.join(v2, "model.npz"), "r+b") as f:
            f.seek(-8, 2)
            f.write(b"\xff" * 8)
        handle = registry.ModelHandle(str(tmp_path), "m")
        with pytest.raises(ValueError, match="verification"):
            handle.set_canary(v2, 0.5)


class TestRollback:
    def test_rollback_repoints_latest_valid(self, tmp_path, fo):
        v1 = registry.publish(str(tmp_path), "m", fo)
        v2 = registry.publish(str(tmp_path), "m", fo)
        assert registry.latest_valid(str(tmp_path), "m") == v2
        assert registry.rollback(str(tmp_path), "m") == v1
        assert registry.latest_valid(str(tmp_path), "m") == v1
        # the retired dir keeps the bits but is invisible to readers
        assert registry.list_versions(str(tmp_path), "m") == [v1]
        retired = registry.list_retired(str(tmp_path), "m")
        assert [os.path.basename(p) for p in retired] == ["retired.v00000002"]
        loaded, manifest = registry.load(retired[0])
        assert manifest["version"] == 2

    def test_rollback_never_reuses_version_numbers(self, tmp_path, fo):
        registry.publish(str(tmp_path), "m", fo)
        registry.publish(str(tmp_path), "m", fo)
        registry.rollback(str(tmp_path), "m")
        v3 = registry.publish(str(tmp_path), "m", fo)
        assert v3.endswith("v00000003")     # v2 is retired, not recycled
        assert registry.latest_valid(str(tmp_path), "m") == v3

    def test_rollback_to_empty_returns_none(self, tmp_path, fo):
        registry.publish(str(tmp_path), "m", fo)
        assert registry.rollback(str(tmp_path), "m") is None
        assert registry.latest_valid(str(tmp_path), "m") is None

    def test_rollback_without_versions_raises(self, tmp_path, fo):
        with pytest.raises(FileNotFoundError):
            registry.rollback(str(tmp_path), "ghost")
        registry.publish(str(tmp_path), "m", fo)
        registry.rollback(str(tmp_path), "m")
        with pytest.raises(FileNotFoundError):
            registry.rollback(str(tmp_path), "m")

    def test_handle_survives_rollback_of_pinned_version(self, tmp_path, fo):
        registry.publish(str(tmp_path), "m", fo)
        v2 = registry.publish(str(tmp_path), "m", fo)
        handle = registry.ModelHandle(str(tmp_path), "m")
        assert handle.stable_path == v2
        registry.rollback(str(tmp_path), "m")
        assert handle.stable is not None    # keeps serving from memory
        assert handle.refresh()             # ...and refresh repoints below
        assert handle.stable_path.endswith("v00000001")


class TestRetention:
    def test_keep_last_on_publish(self, tmp_path, fo):
        for _ in range(5):
            registry.publish(str(tmp_path), "m", fo, keep_last=3)
        names = [os.path.basename(v)
                 for v in registry.list_versions(str(tmp_path), "m")]
        assert names == ["v00000003", "v00000004", "v00000005"]
        assert registry.latest_valid(str(tmp_path), "m").endswith("v00000005")

    def test_gc_versions_reports_removed(self, tmp_path, fo):
        paths = [registry.publish(str(tmp_path), "m", fo) for _ in range(4)]
        removed = registry.gc_versions(str(tmp_path), "m", keep_last=2)
        assert removed == paths[:2]
        assert not any(os.path.exists(p) for p in removed)
        assert registry.gc_versions(str(tmp_path), "m", keep_last=2) == []

    def test_gc_also_prunes_retired(self, tmp_path, fo):
        for _ in range(4):
            registry.publish(str(tmp_path), "m", fo)
            registry.rollback(str(tmp_path), "m")
        assert len(registry.list_retired(str(tmp_path), "m")) == 4
        registry.publish(str(tmp_path), "m", fo, keep_last=2)
        retired = [os.path.basename(p)
                   for p in registry.list_retired(str(tmp_path), "m")]
        assert retired == ["retired.v00000003", "retired.v00000004"]

    def test_keep_last_must_be_positive(self, tmp_path, fo):
        registry.publish(str(tmp_path), "m", fo)
        with pytest.raises(ValueError):
            registry.gc_versions(str(tmp_path), "m", keep_last=0)
