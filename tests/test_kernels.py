"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

SHAPES = [
    # (N, A, B, C, K)
    (64, 1, 4, 2, 3),
    (200, 3, 13, 4, 10),
    (500, 5, 32, 2, 16),
    (130, 2, 7, 23, 5),     # many classes (KDD-style)
    (96, 4, 128, 3, 8),     # wide bins
]


@pytest.mark.parametrize("n,a,b,c,k", SHAPES)
def test_histogram_matches_ref(n, a, b, c, k):
    rng = np.random.default_rng(n + a)
    x = rng.integers(-1, b, (n, a)).astype(np.int32)
    y = rng.integers(0, c, n).astype(np.int32)
    w = rng.uniform(0.1, 2.0, n).astype(np.float32)
    slot = rng.integers(-1, k, n).astype(np.int32)
    got = np.asarray(ops.frontier_histogram(
        x, y, w, slot, n_slots=k, n_bins=b, n_classes=c))
    want = np.asarray(ref.frontier_histogram_ref(
        x, y, w, slot, n_slots=k, n_bins=b, n_classes=c))
    assert got.shape == (k, a, b + 1, c)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("block_t,block_k,block_b", [
    (8, 1, 2), (64, 4, 16), (512, 8, 128)])
def test_histogram_block_shapes(block_t, block_k, block_b):
    rng = np.random.default_rng(3)
    n, a, b, c, k = 150, 2, 9, 3, 6
    x = rng.integers(-1, b, (n, a)).astype(np.int32)
    y = rng.integers(0, c, n).astype(np.int32)
    w = np.ones(n, np.float32)
    slot = rng.integers(-1, k, n).astype(np.int32)
    got = np.asarray(ops.frontier_histogram(
        x, y, w, slot, n_slots=k, n_bins=b, n_classes=c,
        block_t=block_t, block_k=block_k, block_b=block_b))
    want = np.asarray(ref.frontier_histogram_ref(
        x, y, w, slot, n_slots=k, n_bins=b, n_classes=c))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_histogram_conservation():
    """Total kernel mass == total weight of in-frontier known-valued cells."""
    rng = np.random.default_rng(7)
    n, a, b, c, k = 300, 3, 11, 4, 9
    x = rng.integers(-1, b, (n, a)).astype(np.int32)
    y = rng.integers(0, c, n).astype(np.int32)
    w = rng.uniform(0, 1, n).astype(np.float32)
    slot = rng.integers(-1, k, n).astype(np.int32)
    hist = np.asarray(ops.frontier_histogram(
        x, y, w, slot, n_slots=k, n_bins=b, n_classes=c))
    mask = slot >= 0
    assert hist.sum() == pytest.approx(w[mask].sum() * a, rel=1e-5)


@pytest.mark.parametrize("criterion", ["gain", "gain_ratio"])
@pytest.mark.parametrize("k,a,b,c", [(4, 3, 8, 2), (10, 5, 13, 4),
                                     (3, 2, 64, 3)])
def test_split_gain_matches_ref(k, a, b, c, criterion):
    rng = np.random.default_rng(k * a)
    hist = rng.uniform(0, 10, (k, a, b, c)).astype(np.float32)
    tw = hist.sum((1, 2, 3)) / a + rng.uniform(0, 2, k).astype(np.float32)
    cont = rng.random(a) < 0.6
    nb = rng.integers(2, b + 1, a).astype(np.int32)
    got_s, got_b = ops.split_gain(hist, tw.astype(np.float32), cont, nb,
                                  criterion=criterion)
    want_s, want_b = ref.split_gain_ref(hist, tw.astype(np.float32), cont,
                                        nb, criterion=criterion)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 12),
       a=st.integers(1, 6), b=st.integers(2, 20), c=st.integers(2, 6))
def test_split_gain_property_sweep(seed, k, a, b, c):
    rng = np.random.default_rng(seed)
    hist = (rng.uniform(0, 5, (k, a, b, c)) *
            (rng.random((k, a, b, c)) < 0.7)).astype(np.float32)
    tw = hist.sum((1, 2, 3)).astype(np.float32) / max(a, 1)
    cont = rng.random(a) < 0.5
    nb = rng.integers(2, b + 1, a).astype(np.int32)
    got_s, got_b = ops.split_gain(hist, tw, cont, nb)
    want_s, want_b = ref.split_gain_ref(hist, tw, cont, nb)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))


def test_pallas_engine_end_to_end(rng):
    """frontier.build(impl='pallas') == sequential oracle."""
    from conftest import make_tree_dataset
    from repro.core import c45, frontier
    from repro.core.config import GrowConfig
    from repro.core.tree import trees_equal
    ds = make_tree_dataset(rng, 250, n_cont=2, n_disc=1, max_bins=32)
    cfg = GrowConfig(max_nodes=2048, frontier_slots=8)
    t_seq = c45.build(ds, cfg, capacity=2048)
    t_pal = frontier.build(ds, cfg, impl="pallas")
    assert trees_equal(t_seq, t_pal)


FLASH_CASES = [
    # (B, S, H, KV, D, window, softcap, dtype)
    (2, 24, 4, 2, 16, 0, 0.0, "float32"),
    (1, 33, 4, 4, 8, 0, 0.0, "float32"),      # MHA + ragged padding
    (2, 24, 4, 2, 16, 7, 0.0, "float32"),     # sliding window
    (2, 24, 4, 2, 16, 0, 30.0, "float32"),    # softcap (gemma2)
    (2, 40, 6, 2, 32, 9, 50.0, "float32"),    # window + softcap + GQA 3
    (2, 32, 4, 2, 16, 0, 0.0, "bfloat16"),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_pallas_flash_attention_matches_jnp(case):
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_attention
    from repro.models import layers
    from repro.models.layers import AttnSpec
    b, s, h, kv, d, window, cap, dtype = case
    rng = np.random.default_rng(b * s)
    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), dt)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), dt)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kv, d)), dt)
    spec = AttnSpec(n_heads=h, n_kv_heads=kv, head_dim=d, d_model=h * d,
                    window=window, softcap=cap, dtype=dt)
    want = layers.blockwise_attention(q, k, v, spec=spec, q_chunk=8,
                                      kv_chunk=8)
    got = flash_attention(q, k, v, window=window, softcap=cap, q_chunk=8,
                          kv_chunk=8, interpret=True)
    tol = 3e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
