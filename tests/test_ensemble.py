"""Ensemble trainer: farm determinism, chaos acceptance, OOB, publishing.

The load-bearing guarantees:

  * the forest is a pure function of ``(dataset, ForestConfig)`` — worker
    count, scheduling order and injected chaos cannot change a bit of it
    (tree tasks are pure in ``(seed, tree_id)``, results keyed by id);
  * both growth engines (per-tree c45 oracle, jitted frontier superstep)
    grow identical trees from the same bootstrap weights + feature mask;
  * the acceptance flow: chaos-trained forest == sequential oracle, finite
    OOB score recorded at publish, and the published version serves
    predictions through ``infer.service`` that match
    ``Forest.predict(impl="ref")``.
"""

import numpy as np
import pytest

from conftest import make_tree_dataset, run_with_timeout
from repro.core import faults
from repro.core.config import GrowConfig
from repro.core.farm import FaultPolicy
from repro.core.tree import trees_equal
from repro.ensemble import (ForestConfig, QuarantinedTrees, oob, publish,
                            sampling, trainer)
from repro.infer import forest as F
from repro.infer import registry
from repro.infer.service import (BatchPredictService, InferReplica,
                                 PredictRequest)
from repro.obs.metrics import Registry

pytestmark = pytest.mark.timeout(300)

GROW = GrowConfig(max_nodes=1 << 12)


def _dataset(seed=0, n=300, **kw):
    rng = np.random.default_rng(seed)
    kw.setdefault("n_cont", 2)
    kw.setdefault("n_disc", 2)
    kw.setdefault("n_classes", 3)
    return make_tree_dataset(rng, n, **kw)


def _forests_equal(a, b):
    return len(a) == len(b) and all(trees_equal(x, y) for x, y in zip(a, b))


# ------------------------------------------------------------------ sampling

class TestSampling:
    def test_pure_in_seed_and_tree_id(self):
        a = sampling.draw(3, 5, n_cases=100, n_attrs=7)
        b = sampling.draw(3, 5, n_cases=100, n_attrs=7)
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.attr_mask, b.attr_mask)
        c = sampling.draw(3, 6, n_cases=100, n_attrs=7)
        assert not np.array_equal(a.counts, c.counts) \
            or not np.array_equal(a.attr_mask, c.attr_mask)

    def test_bootstrap_preserves_total_draws(self):
        counts = sampling.bootstrap_counts(0, 0, 500)
        assert counts.sum() == 500
        assert (counts == 0).any()          # ~36.8% of cases are OOB

    def test_feature_mask_size_and_bounds(self):
        m = sampling.feature_mask(0, 0, 9)
        assert m.sum() == sampling.default_mtry(9) == 3
        assert sampling.feature_mask(0, 0, 9, mtry=9).all()
        with pytest.raises(ValueError):
            sampling.feature_mask(0, 0, 9, mtry=10)
        with pytest.raises(ValueError):
            sampling.feature_mask(0, 0, 9, mtry=0)

    def test_no_bootstrap_keeps_base_weights(self):
        s = sampling.draw(0, 0, n_cases=10, n_attrs=3, bootstrap=False,
                          base_w=np.full(10, 2.0, np.float32))
        np.testing.assert_array_equal(s.case_w, np.full(10, 2.0))
        assert not s.oob.any()


# ------------------------------------------------------- farm determinism

class TestFarmDeterminism:
    def test_forest_identical_across_worker_counts(self):
        ds = _dataset()
        fc = ForestConfig(n_trees=5, seed=2, grow=GROW)
        seq = trainer.train_forest_sequential(ds, fc)
        for n_workers in (1, 4):
            res = run_with_timeout(
                lambda: trainer.train_forest(ds, fc, n_workers=n_workers),
                120)
            assert res.tree_ids == list(range(5))
            assert _forests_equal(seq, res.trees), \
                f"forest diverged at n_workers={n_workers}"

    def test_chaos_run_equals_oracle(self):
        """Acceptance: crash_p=0.2 + a permanently dead worker -> identical
        forest, with real retries exercised."""
        ds = _dataset()
        fc = ForestConfig(n_trees=8, seed=0, grow=GROW)
        seq = trainer.train_forest_sequential(ds, fc)
        inj = faults.FaultInjector(
            seed=7, spec=faults.FaultSpec(
                crash_p=0.2, dead_workers=frozenset({1})),
            key_fn=lambda tid: tid)
        stats = {}
        res = run_with_timeout(
            lambda: trainer.train_forest(
                ds, fc, n_workers=4, injector=inj,
                fault=FaultPolicy(max_retries=8, seed=3, backoff_base=1e-4),
                stats_out=stats), 240)
        assert _forests_equal(seq, res.trees), \
            "chaos forest diverged from the sequential oracle"
        assert stats["dead_workers"] == [1]
        assert stats["failures"] > 0 and stats["retries"] > 0
        assert stats["quarantined"] == 0 and not res.quarantined

    def test_frontier_impl_matches_c45(self):
        ds = _dataset(seed=4)
        fc = ForestConfig(n_trees=4, seed=5, grow=GROW)
        seq = trainer.train_forest_sequential(ds, fc, impl="c45")
        fro = trainer.train_forest_sequential(ds, fc, impl="frontier")
        assert _forests_equal(seq, fro)

    def test_farm_build_engine_accepts_same_hooks(self):
        """All three engines share the attr_mask/case_w contract."""
        from repro.core import farm_build
        ds = _dataset(seed=8, n=200)
        s = sampling.draw(0, 0, n_cases=ds.n_cases, n_attrs=ds.n_attrs,
                          base_w=ds.w)
        from repro.core import c45
        want = c45.build(ds, GROW, attr_mask=s.attr_mask, case_w=s.case_w)
        got = run_with_timeout(
            lambda: farm_build.build(ds, GROW, n_workers=3,
                                     attr_mask=s.attr_mask,
                                     case_w=s.case_w), 120)
        assert trees_equal(want, got)

    def test_feature_mask_actually_restricts_splits(self):
        ds = _dataset(seed=1)
        fc = ForestConfig(n_trees=4, seed=3, mtry=1, grow=GROW)
        for tid, tree in enumerate(trainer.train_forest_sequential(ds, fc)):
            mask = sampling.feature_mask(fc.seed, tid, ds.n_attrs, 1)
            used = np.asarray(tree.node_attr)[:tree.size]
            used = set(used[used >= 0].tolist())
            allowed = set(np.nonzero(mask)[0].tolist())
            assert used <= allowed, f"tree {tid} split outside its subset"

    def test_strict_quarantine_raises_nonstrict_drops(self):
        ds = _dataset(seed=6, n=150)
        fc = ForestConfig(n_trees=3, seed=1, grow=GROW)
        # tree 1 poisoned: crashes on every attempt
        inj = faults.FaultInjector(
            seed=0, spec=faults.FaultSpec(crash_p=1.0),
            key_fn=lambda tid: "poison" if tid == 1 else f"ok{tid}")
        inj.decide = lambda key, call, _d=inj.decide: \
            "crash" if key == "poison" else "ok"
        fault = FaultPolicy(max_retries=1, backoff_base=0.0)
        with pytest.raises(QuarantinedTrees):
            run_with_timeout(
                lambda: trainer.train_forest(ds, fc, n_workers=2,
                                             injector=inj, fault=fault), 120)
        inj2 = faults.FaultInjector(
            seed=0, spec=faults.FaultSpec(crash_p=1.0),
            key_fn=lambda tid: "poison" if tid == 1 else f"ok{tid}")
        inj2.decide = lambda key, call: \
            "crash" if key == "poison" else "ok"
        res = run_with_timeout(
            lambda: trainer.train_forest(ds, fc, n_workers=2, injector=inj2,
                                         fault=fault, strict=False), 120)
        assert res.quarantined == [1]
        assert res.tree_ids == [0, 2]
        seq = trainer.train_forest_sequential(ds, fc)
        assert trees_equal(res.trees[0], seq[0])
        assert trees_equal(res.trees[1], seq[2])

    def test_trainer_metrics_and_spans(self):
        from repro.obs.trace import Tracer
        ds = _dataset(seed=2, n=150)
        fc = ForestConfig(n_trees=3, seed=0, grow=GROW)
        reg = Registry()
        tracer = Tracer()
        run_with_timeout(
            lambda: trainer.train_forest(ds, fc, n_workers=2, metrics=reg,
                                         tracer=tracer), 120)
        assert reg.get("ensemble_trees_trained_total").value(impl="c45") == 3
        assert reg.get("ensemble_trees_per_s").value(impl="c45") > 0
        names = {e.get("name") for e in tracer.events}
        assert "ensemble.tree" in names


# ------------------------------------------------------------------- OOB

class TestOOB:
    def test_oob_score_finite_and_bounded(self):
        ds = _dataset()
        fc = ForestConfig(n_trees=8, seed=0, grow=GROW)
        res = run_with_timeout(
            lambda: trainer.train_forest(ds, fc, n_workers=2), 120)
        r = oob.oob_score(res.trees, ds, fc, tree_ids=res.tree_ids)
        assert np.isfinite(r.score) and 0.0 <= r.score <= 1.0
        assert r.coverage > 0.5
        assert r.pred.shape == (ds.n_cases,)
        covered = r.pred >= 0
        assert covered.sum() == r.n_covered

    def test_oob_ignores_in_bag_trees(self):
        """A case's OOB vote must only see trees whose bootstrap missed it."""
        ds = _dataset(seed=3, n=200)
        fc = ForestConfig(n_trees=5, seed=7, grow=GROW)
        trees = trainer.train_forest_sequential(ds, fc)
        m = oob.oob_matrix(fc, ds.n_cases)
        for t in range(fc.n_trees):
            counts = sampling.bootstrap_counts(fc.seed, t, ds.n_cases)
            np.testing.assert_array_equal(m[t], counts == 0)
        r = oob.oob_score(trees, ds, fc)
        uncovered = ~m.any(axis=0)
        assert (r.pred[uncovered] == -1).all()

    def test_oob_requires_bootstrap(self):
        ds = _dataset(n=100)
        fc = ForestConfig(n_trees=2, seed=0, bootstrap=False, grow=GROW)
        trees = trainer.train_forest_sequential(ds, fc)
        with pytest.raises(ValueError, match="bootstrap"):
            oob.oob_score(trees, ds, fc)

    def test_permutation_importance_flags_signal_column(self):
        # Build a dataset whose label is a noisy threshold of column 0 (the
        # conftest generator keeps y marginally uniform, i.e. signal-free);
        # permuting col 0 must hurt far more than the noise columns.
        from repro.core import binning
        rng = np.random.default_rng(0)
        n = 500
        c0 = rng.uniform(-2, 2, n)
        noise = [rng.uniform(-2, 2, n), rng.integers(0, 3, n)]
        y = (c0 > 0).astype(np.int64)
        y = np.where(rng.random(n) < 0.1, 1 - y, y)    # 10% label noise
        ds = binning.fit([c0, *noise], y,
                         attr_is_cont=[True, True, False], n_classes=2,
                         max_bins=32)
        fc = ForestConfig(n_trees=12, seed=2, mtry=2, grow=GROW)
        trees = trainer.train_forest_sequential(ds, fc)
        imp = oob.permutation_importance(trees, ds, fc, n_repeats=2)
        assert imp.shape == (ds.n_attrs,)
        assert imp[0] == imp.max()
        assert imp[0] > 0

    def test_permutation_importance_is_deterministic(self):
        ds = _dataset(seed=5, n=200)
        fc = ForestConfig(n_trees=4, seed=1, grow=GROW)
        trees = trainer.train_forest_sequential(ds, fc)
        a = oob.permutation_importance(trees, ds, fc)
        b = oob.permutation_importance(trees, ds, fc)
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------- publish + serving

class TestPublishServe:
    def test_acceptance_chaos_train_publish_serve(self, tmp_path):
        """The issue's acceptance flow, end to end: chaos-trained forest ==
        oracle, finite OOB in the manifest, registry round-trip through
        infer.service matching Forest.predict(impl="ref")."""
        ds = _dataset()
        fc = ForestConfig(n_trees=6, seed=1, grow=GROW)
        seq = trainer.train_forest_sequential(ds, fc)
        inj = faults.FaultInjector(
            seed=7, spec=faults.FaultSpec(
                crash_p=0.2, dead_workers=frozenset({1})),
            key_fn=lambda tid: tid)
        stats = {}
        res = run_with_timeout(
            lambda: trainer.train_forest(
                ds, fc, n_workers=4, injector=inj,
                fault=FaultPolicy(max_retries=8, backoff_base=1e-4),
                stats_out=stats), 240)
        assert _forests_equal(seq, res.trees)
        assert stats["dead_workers"] == [1]

        path = publish.publish_forest(str(tmp_path), "rf", res, ds)
        meta = registry.manifest_of(path)["metadata"]
        assert np.isfinite(meta["oob_score"])
        assert meta["seed"] == 1 and meta["n_trees"] == 6
        assert meta["mtry"] == fc.resolved_mtry(ds.n_attrs)

        loaded, _ = registry.load(path)
        want = np.asarray(F.predict(loaded, ds.x, ds.attr_is_cont,
                                    impl="ref"))
        handle = registry.ModelHandle(str(tmp_path), "rf")
        svc = BatchPredictService(
            [InferReplica.from_handle(handle, ds.attr_is_cont)
             for _ in range(2)],
            handle=handle, max_batch=64, metrics=Registry())
        n = ds.n_cases
        for uid in range(n):
            svc.submit(PredictRequest(uid=uid, x_row=ds.x[uid]))
        results = run_with_timeout(svc.run_until_drained, 120)
        assert len(results) == n and not svc.failed
        got = np.zeros(n, np.int64)
        for r in results:
            got[r.uid] = r.label
        np.testing.assert_array_equal(got, want)

    def test_publish_forest_metadata_without_oob(self, tmp_path):
        ds = _dataset(n=120)
        fc = ForestConfig(n_trees=2, seed=0, bootstrap=False, grow=GROW)
        res = run_with_timeout(
            lambda: trainer.train_forest(ds, fc, n_workers=1), 120)
        path = publish.publish_forest(str(tmp_path), "rf", res, ds)
        meta = registry.manifest_of(path)["metadata"]
        assert meta["bootstrap"] is False
        assert "oob_score" not in meta
        assert meta["tree_ids"] == [0, 1]
