"""Data substrate: QUEST generator, Table-1 stand-ins, sharded loader."""

import numpy as np
import pytest

from repro.data import datasets, quest
from repro.data.loader import LoaderConfig, ShardedLoader


def test_quest_schema_matches_table1():
    ds = quest.generate(2_000, function=5, seed=0)
    spec = datasets.TABLE1["syd10m9a"]
    assert ds.n_attrs == 9
    assert int(ds.attr_is_cont.sum()) == spec.n_continuous == 6
    assert int((~ds.attr_is_cont).sum()) == spec.n_discrete == 3
    assert ds.n_classes == 2
    # label noise default 5%: both classes present
    assert set(np.unique(ds.y)) == {0, 1}


def test_quest_function5_learnable():
    from repro.core import GrowConfig, predict
    from repro.core import frontier
    ds = quest.generate(4_000, function=5, seed=1, perturbation=0.0)
    tree = frontier.build(ds, GrowConfig(max_nodes=1 << 13,
                                         frontier_slots=64))
    pred = np.asarray(predict(tree, ds.x, ds.attr_is_cont))
    assert (pred == ds.y).mean() > 0.97     # age/salary/loan bands are crisp


def test_quest_deterministic():
    a = quest.generate(500, seed=7)
    b = quest.generate(500, seed=7)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.y, b.y)


@pytest.mark.parametrize("name", list(datasets.TABLE1))
def test_table1_standins_schema(name):
    spec = datasets.TABLE1[name]
    ds = datasets.load(name, scale=0.002)
    assert ds.n_attrs == spec.n_discrete + spec.n_continuous
    assert int(ds.attr_is_cont.sum()) == spec.n_continuous
    assert ds.n_classes == spec.n_classes


def test_loader_determinism_and_seek():
    cfg = LoaderConfig(global_batch=4, seq_len=32, vocab_size=1000, seed=3)
    a = ShardedLoader(cfg)
    b = ShardedLoader(cfg)
    ba0, ba1 = a.next_batch(), a.next_batch()
    b.seek(1)
    bb1 = b.next_batch()
    np.testing.assert_array_equal(ba1["tokens"], bb1["tokens"])
    assert not np.array_equal(ba0["tokens"], ba1["tokens"])


def test_loader_host_sharding_partitions_batch():
    cfg = LoaderConfig(global_batch=8, seq_len=16, vocab_size=512, seed=0)
    full = ShardedLoader(cfg).next_batch()
    h0 = ShardedLoader(cfg, host_index=0, num_hosts=2).next_batch()
    h1 = ShardedLoader(cfg, host_index=1, num_hosts=2).next_batch()
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])


def test_loader_labels_are_shifted_tokens():
    cfg = LoaderConfig(global_batch=2, seq_len=16, vocab_size=128, seed=1)
    b = ShardedLoader(cfg).next_batch()
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    # same underlying block: labels = tokens shifted by one
    cfg2 = LoaderConfig(global_batch=2, seq_len=16, vocab_size=128, seed=1)
    src = ShardedLoader(cfg2).source.block(0, 0, 2)
    np.testing.assert_array_equal(b["tokens"], src[:, :-1])
    np.testing.assert_array_equal(b["labels"], src[:, 1:])


def test_loader_state_roundtrip():
    cfg = LoaderConfig(global_batch=2, seq_len=8, vocab_size=64)
    a = ShardedLoader(cfg)
    a.next_batch(); a.next_batch()
    state = a.state_dict()
    b = ShardedLoader(cfg)
    b.load_state_dict(state)
    np.testing.assert_array_equal(a.next_batch()["tokens"],
                                  b.next_batch()["tokens"])
