"""Property: the SPMD frontier engine is exactly the sequential oracle."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from conftest import make_tree_dataset
from repro.core import c45, frontier
from repro.core.config import GrowConfig
from repro.core.tree import predict, trees_equal


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(30, 500),
    n_cont=st.integers(0, 3),
    n_disc=st.integers(0, 3),
    n_classes=st.integers(2, 4),
    slots=st.sampled_from([2, 7, 64]),
    unknown=st.sampled_from([0.0, 0.15]),
)
def test_engines_identical(seed, n, n_cont, n_disc, n_classes, slots,
                           unknown):
    if n_cont + n_disc == 0:
        n_cont = 1
    rng = np.random.default_rng(seed)
    ds = make_tree_dataset(rng, n, n_cont=n_cont, n_disc=n_disc,
                           n_classes=n_classes, unknown_frac=unknown)
    cfg = GrowConfig(max_nodes=1 << 13, frontier_slots=slots)
    t_seq = c45.build(ds, cfg, capacity=cfg.max_nodes)
    t_ff = frontier.build(ds, cfg)
    assert trees_equal(t_seq, t_ff), (
        f"trees differ: seq={t_seq.size} ff={t_ff.size}")
    p1 = np.asarray(predict(t_seq, ds.x, ds.attr_is_cont))
    p2 = np.asarray(predict(t_ff, ds.x, ds.attr_is_cont))
    assert (p1 == p2).all()


def test_capacity_overflow_degrades_gracefully(rng):
    ds = make_tree_dataset(rng, 500, n_cont=3, n_disc=2, n_classes=3)
    cfg = GrowConfig(max_nodes=16, frontier_slots=8)
    tree = frontier.build(ds, cfg)          # must not error
    assert tree.size <= 16
    pred = np.asarray(predict(tree, ds.x, ds.attr_is_cont))
    assert pred.shape == (500,)


def test_max_depth_respected(rng):
    ds = make_tree_dataset(rng, 400, n_cont=2, n_disc=2)
    cfg = GrowConfig(max_depth=3, max_nodes=4096)
    t_seq = c45.build(ds, cfg, capacity=4096)
    t_ff = frontier.build(ds, cfg)
    assert trees_equal(t_seq, t_ff)
    assert t_ff.depth <= 3


def test_collect_stats_reports_cost_model(rng):
    ds = make_tree_dataset(rng, 600, n_cont=2, n_disc=1)
    cfg = GrowConfig(frontier_slots=16, cost_model="nsq", max_nodes=8192)
    tree, stats = frontier.build(ds, cfg, collect_stats=True)
    assert len(stats) >= 1
    assert stats[0]["n_processed"] == 1           # root superstep
    # NAP is chosen at the root (coarse grain) under |T| < c r^2
    assert stats[0]["nap_nodes"] == 1
    assert sum(s["n_processed"] for s in stats) == tree.size
