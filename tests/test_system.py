"""End-to-end behaviour tests for the whole system."""

import numpy as np
import pytest


def test_paper_pipeline_end_to_end(rng):
    """QUEST data -> SPMD tree growth -> farm replay: the paper's full loop."""
    from repro.core import GrowConfig, predict, trees_equal
    from repro.core import c45, frontier, simulate
    from repro.data import quest

    ds = quest.generate(3_000, function=5, seed=0, perturbation=0.02)
    cfg = GrowConfig(max_nodes=1 << 13, frontier_slots=64)
    trace = []
    t_seq = c45.build(ds, cfg, task_trace=trace, capacity=cfg.max_nodes)
    t_ff = frontier.build(ds, cfg)
    assert trees_equal(t_seq, t_ff)
    acc = (np.asarray(predict(t_ff, ds.x, ds.attr_is_cont)) == ds.y).mean()
    assert acc > 0.9

    cm = simulate.calibrate(trace, measured_seq_seconds=1.0)
    nap = simulate.simulate(trace, n_workers=8, strategy="nap",
                            policy="ws", cost=cm)
    np_ = simulate.simulate(trace, n_workers=8, strategy="np",
                            policy="ws", cost=cm)
    assert nap.speedup > np_.speedup          # the paper's headline result
    assert nap.speedup > 2.0


def test_lm_training_learns_and_checkpoints(tmp_path):
    from repro.launch.train import train
    out = train("gemma3_4b", reduced=True, steps=8, global_batch=4,
                seq_len=64, ckpt_dir=str(tmp_path), ckpt_every=4,
                log_every=100)
    assert out["last_loss"] < out["first_loss"]
    from repro.train import checkpoint as ckpt
    assert ckpt.latest_valid(str(tmp_path)) is not None


def test_serving_round_trip():
    from repro.launch.serve import serve
    out = serve("yi_6b", reduced=True, n_requests=5, n_replicas=1,
                n_slots=2, max_new=4)
    assert out["completed"] == 5
    assert out["tokens"] == 5 * 4
