"""Fault tolerance control plane: heartbeats, elastic mesh, stragglers."""

import pytest

from repro.train import elastic


def test_heartbeat_failure_detection():
    hb = elastic.HeartbeatMonitor(timeout=10.0)
    hb.beat("h0", 1, now=0.0)
    hb.beat("h1", 1, now=0.0)
    hb.beat("h0", 2, now=8.0)
    assert hb.failed(now=11.0) == ["h1"]
    assert hb.alive(now=11.0) == ["h0"]


def test_plan_mesh_full_fleet():
    shape, axes = elastic.plan_mesh(512)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    shape, axes = elastic.plan_mesh(256)
    assert shape == (16, 16) and axes == ("data", "model")


def test_plan_mesh_degraded():
    # lose 3 chips out of a pod: data width shrinks, model anchor holds
    shape, axes = elastic.plan_mesh(253)
    assert axes == ("data", "model")
    assert shape == (15, 16)
    with pytest.raises(ValueError):
        elastic.plan_mesh(7)


def test_rebatch_for_mesh_keeps_per_replica_batch():
    gb = elastic.rebatch_for_mesh(256, (16, 16), ("data", "model"))
    assert gb == 256
    gb = elastic.rebatch_for_mesh(256, (15, 16), ("data", "model"))
    assert gb % 15 == 0 and gb <= 256  # divisible by the new DP width


def test_straggler_detection_and_ws_weights():
    sm = elastic.StragglerMonitor(factor=1.5)
    for _ in range(8):
        sm.record("fast0", 1.0)
        sm.record("fast1", 1.1)
        sm.record("slow", 2.5)
    assert sm.stragglers() == ["slow"]
    w = sm.ws_weights()
    assert w["slow"] < w["fast0"]          # slow host gets less work


def test_elastic_restore_roundtrip(tmp_path):
    """Checkpoint written under one 'mesh' restores under another (the
    on-disk format is mesh-agnostic)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.train import checkpoint as ckpt
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    path = ckpt.save(str(tmp_path), 1, state)
    like = {"w": jnp.zeros((8, 8))}
    restored = ckpt.restore(path, like)    # would pass shardings on a pod
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
