"""Acceptance: farm-built trees are oracle-equal even under injected chaos.

The seeded :class:`~repro.core.faults.FaultInjector` crashes task attempts
at p=0.2 and kills one worker permanently; the supervised farm must retry /
re-dispatch until the full C4.5 tree is grown, elementwise-equal to the
sequential oracle, without ever deadlocking (``run_with_timeout`` turns a
hang into a failure).
"""

import numpy as np
import pytest

from conftest import make_tree_dataset, run_with_timeout
from repro.core import c45, faults, frontier
from repro.core.config import GrowConfig
from repro.core.farm import FaultPolicy
from repro.core.farm_build import QuarantinedNodes, build
from repro.core.tree import predict, trees_equal

pytestmark = pytest.mark.timeout(300)

CFG = GrowConfig(max_nodes=1 << 13)


def _dataset(seed=0, n=400, **kw):
    rng = np.random.default_rng(seed)
    kw.setdefault("n_cont", 2)
    kw.setdefault("n_disc", 2)
    kw.setdefault("n_classes", 3)
    return make_tree_dataset(rng, n, **kw)


def test_farm_build_matches_oracle_without_faults():
    ds = _dataset()
    t_seq = c45.build(ds, CFG)
    t_farm = run_with_timeout(lambda: build(ds, CFG, n_workers=4), 120)
    assert trees_equal(t_seq, t_farm)


def test_farm_build_handles_unknowns_and_fractional_weights():
    ds = _dataset(seed=3, unknown_frac=0.15)
    for fractional in (False, True):
        cfg = GrowConfig(max_nodes=1 << 13, unknown_fractional=fractional)
        t_seq = c45.build(ds, cfg)
        t_farm = run_with_timeout(lambda: build(ds, cfg, n_workers=3), 120)
        assert trees_equal(t_seq, t_farm)


def test_farm_build_oracle_equal_under_seeded_chaos():
    """crash_p=0.2 + one permanently dead worker -> identical tree."""
    ds = _dataset()
    t_seq = c45.build(ds, CFG)
    inj = faults.FaultInjector(seed=7, spec=faults.FaultSpec(
        crash_p=0.2, slow_p=0.1, slow_s=0.002,
        dead_workers=frozenset({1})), key_fn=lambda t: t.node_id)
    stats = {}
    t_chaos = run_with_timeout(
        lambda: build(ds, CFG, n_workers=4,
                      fault=FaultPolicy(max_retries=8, seed=3,
                                        backoff_base=1e-4),
                      injector=inj, stats_out=stats), 240)
    assert trees_equal(t_seq, t_chaos), "chaos build diverged from oracle"
    p1 = np.asarray(predict(t_seq, ds.x, ds.attr_is_cont))
    p2 = np.asarray(predict(t_chaos, ds.x, ds.attr_is_cont))
    assert (p1 == p2).all()
    assert stats["failures"] > 0 and stats["retries"] > 0
    assert stats["quarantined"] == 0
    assert stats["dead_workers"] == [1]


def test_farm_build_chaos_is_replayable():
    """Same seed -> same fault schedule -> same farm stats."""
    ds = _dataset(seed=5, n=250)

    def run_once():
        inj = faults.FaultInjector(seed=11, spec=faults.FaultSpec(
            crash_p=0.25), key_fn=lambda t: t.node_id)
        stats = {}
        tree = build(ds, CFG, n_workers=3,
                     fault=FaultPolicy(max_retries=8, backoff_base=0.0),
                     injector=inj, stats_out=stats)
        return tree, stats["failures"], stats["retries"]

    t1, f1, r1 = run_with_timeout(run_once, 120)
    t2, f2, r2 = run_with_timeout(run_once, 120)
    assert trees_equal(t1, t2)
    assert (f1, r1) == (f2, r2)


def test_farm_build_quarantine_degrades_node_to_leaf():
    ds = _dataset(seed=9, n=200)
    inj = faults.FaultInjector(seed=0, spec=faults.FaultSpec(crash_p=1.0),
                               key_fn=lambda t: t.node_id)
    fault = FaultPolicy(max_retries=1, backoff_base=0.0)
    with pytest.raises(QuarantinedNodes):
        run_with_timeout(
            lambda: build(ds, CFG, n_workers=2, fault=fault, injector=inj),
            120)
    # non-strict: the poisoned root degrades to a single-leaf tree
    tree = run_with_timeout(
        lambda: build(ds, CFG, n_workers=2, fault=fault,
                      injector=faults.FaultInjector(
                          seed=0, spec=faults.FaultSpec(crash_p=1.0),
                          key_fn=lambda t: t.node_id),
                      strict=False), 120)
    assert tree.size == 1
    pred = np.asarray(predict(tree, ds.x, ds.attr_is_cont))
    assert pred.shape == (200,)


def test_frontier_build_farm_entrypoint():
    ds = _dataset(seed=2, n=150)
    t_seq = c45.build(ds, CFG)
    t_farm = run_with_timeout(
        lambda: frontier.build_farm(ds, CFG, n_workers=2), 120)
    assert trees_equal(t_seq, t_farm)
