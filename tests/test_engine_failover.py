"""Serving engine fault tolerance: replica failover, deadlines, drain.

Uses the same reduced model as test_serve.py; replicas are killed through
the deterministic :class:`~repro.core.faults.ChaosReplica` proxy.  The
invariant under test: every submitted request ends as exactly one
``Completion`` or one explicit ``RequestFailure`` — never silently dropped.
"""

import jax
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.core.faults import ChaosReplica
from repro.models.model import build_model
from repro.serve.engine import Replica, Request, ServingEngine
from repro.train.elastic import HeartbeatMonitor

pytestmark = pytest.mark.timeout(600)


@pytest.fixture(scope="module")
def small_model():
    cfg = cfgbase.reduced(cfgbase.get_config("yi_6b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n, *, seed=0, max_new=4, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(4, 20))
                                        ).astype(np.int32),
                    max_new_tokens=max_new, **kw)
            for i in range(n)]


def _terminal_uids(eng):
    return sorted([c.uid for c in eng.completed] +
                  [f.uid for f in eng.failed])


def test_replica_killed_mid_run_fails_over(small_model):
    cfg, model, params = small_model
    victim = ChaosReplica(Replica(model, params, n_slots=2, max_seq=64),
                          fail_at_tick=2)
    survivor = Replica(model, params, n_slots=2, max_seq=64)
    eng = ServingEngine([victim, survivor], max_requeues=2)
    reqs = _requests(cfg, 6)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_ticks=500)
    # accounting: every request has exactly one terminal record
    assert _terminal_uids(eng) == list(range(6))
    assert eng.healthy == [False, True]
    # the victim's in-flight requests were re-admitted and completed
    assert len(eng.completed) == 6
    assert eng.stats()["requeues"] >= 1
    assert eng.stats()["evicted_replicas"] == [0]


def test_all_replicas_dead_reports_every_request(small_model):
    cfg, model, params = small_model
    rep = ChaosReplica(Replica(model, params, n_slots=2, max_seq=64),
                       fail_at_tick=1)
    eng = ServingEngine([rep])
    for r in _requests(cfg, 4):
        eng.submit(r)
    out = eng.run_until_drained(max_ticks=200)
    assert out == []
    assert _terminal_uids(eng) == list(range(4))
    reasons = {f.reason for f in eng.failed}
    assert reasons <= {"no_replicas", "requeue_exhausted"}
    assert eng.stats()["healthy_replicas"] == 0


def test_admit_race_requeues_instead_of_crashing(small_model):
    cfg, model, params = small_model
    rep = ChaosReplica(Replica(model, params, n_slots=2, max_seq=64),
                       admit_failures=1)
    eng = ServingEngine([rep], max_requeues=3)
    for r in _requests(cfg, 3):
        eng.submit(r)
    eng.run_until_drained(max_ticks=300)
    assert sorted(c.uid for c in eng.completed) == [0, 1, 2]
    assert eng.failed == []
    assert eng.healthy == [True]           # a race is not a replica death


def test_request_deadline_yields_explicit_timeout(small_model):
    cfg, model, params = small_model
    eng = ServingEngine([Replica(model, params, n_slots=2, max_seq=128)])
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=64,
                       deadline_ticks=3))
    eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=2))
    eng.run_until_drained(max_ticks=300)
    assert [c.uid for c in eng.completed] == [1]
    (fail,) = eng.failed
    assert (fail.uid, fail.reason) == (0, "timeout")
    assert 0 < len(fail.tokens) < 64       # partial decode surfaced


def test_max_ticks_reports_undrained_requests(small_model):
    cfg, model, params = small_model
    eng = ServingEngine([Replica(model, params, n_slots=1, max_seq=64)])
    for r in _requests(cfg, 3, max_new=8):
        eng.submit(r)
    eng.run_until_drained(max_ticks=2)     # nowhere near enough
    assert _terminal_uids(eng) == [0, 1, 2]
    assert any(f.reason == "max_ticks" for f in eng.failed)


def test_heartbeat_eviction_requeues_inflight(small_model):
    cfg, model, params = small_model
    reps = [Replica(model, params, n_slots=2, max_seq=64) for _ in range(2)]
    hb = HeartbeatMonitor(timeout=5)       # measured in engine ticks
    eng = ServingEngine(reps, heartbeat=hb, max_requeues=2)
    # replica0 reported a beat far in the past: declared failed on tick 1
    hb.beat("replica0", now=-100)
    for r in _requests(cfg, 4):
        eng.submit(r)
    eng.run_until_drained(max_ticks=500)
    assert eng.healthy == [False, True]
    assert len(eng.completed) == 4
    assert _terminal_uids(eng) == list(range(4))


def test_failure_breakdown_stats(small_model):
    cfg, model, params = small_model
    rep = ChaosReplica(Replica(model, params, n_slots=2, max_seq=64),
                       fail_at_tick=1)
    eng = ServingEngine([rep])
    for r in _requests(cfg, 2):
        eng.submit(r)
    eng.run_until_drained(max_ticks=100)
    s = eng.stats()
    assert s["completed"] == 0 and s["failed"] == 2
    assert sum(s["failed_by_reason"].values()) == 2
    assert s["evicted_replicas"] == [0]
