"""Microbatching predict service: coalescing, scheduling, failover, obs."""

import numpy as np
import pytest
from conftest import make_tree_dataset, run_with_timeout

from repro.core import c45
from repro.core.config import GrowConfig
from repro.infer import registry
from repro.infer.forest import Forest
from repro.infer.service import (BatchPredictService, InferReplica,
                                 PredictRequest, _Batch)
from repro.obs.metrics import Registry
from repro.obs.trace import Tracer


@pytest.fixture
def ds(rng):
    return make_tree_dataset(rng, n=300, unknown_frac=0.1)


@pytest.fixture
def fo(ds):
    return Forest.pack([c45.build(ds, GrowConfig())])


def _submit(svc, ds, n, start=0):
    for u in range(start, start + n):
        svc.submit(PredictRequest(uid=u, x_row=ds.x[u % ds.n_cases]))


def _expected(ds, fo, uids):
    from repro.infer.forest import predict
    labels = np.asarray(predict(fo, ds.x, ds.attr_is_cont))
    return {u: int(labels[u % ds.n_cases]) for u in uids}


class FlakyReplica(InferReplica):
    """Dies (tick raises) after serving ``fail_after`` batches."""

    def __init__(self, *a, fail_after=1, **kw):
        super().__init__(*a, **kw)
        self.fail_after = fail_after
        self.served = 0

    def tick(self):
        if self.queue and self.served >= self.fail_after:
            raise RuntimeError("injected replica death")
        out = super().tick()
        if out[0]:
            self.served += 1
        return out


class TestMicrobatching:
    def test_full_batches_close_immediately(self, ds, fo):
        reg = Registry()
        svc = BatchPredictService(
            [InferReplica.from_forest(fo, ds.attr_is_cont)],
            max_batch=32, max_wait_ticks=50, metrics=reg)
        _submit(svc, ds, 64)
        res = run_with_timeout(svc.run_until_drained)
        assert len(res) == 64 and not svc.failed
        hist = reg.get("infer_batch_rows")._snapshot_series()[0]
        assert hist["count"] == 2          # two full 32-row batches
        assert hist["sum"] == 64
        # nothing waited for the age-out path
        assert svc.stats()["ticks"] < 50

    def test_stragglers_age_out_after_max_wait(self, ds, fo):
        svc = BatchPredictService(
            [InferReplica.from_forest(fo, ds.attr_is_cont)],
            max_batch=64, max_wait_ticks=3)
        _submit(svc, ds, 10)               # far below max_batch
        res = run_with_timeout(svc.run_until_drained)
        assert len(res) == 10 and not svc.failed
        assert all(r.batch_size == 10 for r in res)

    def test_labels_match_direct_forest_predict(self, ds, fo):
        svc = BatchPredictService(
            [InferReplica.from_forest(fo, ds.attr_is_cont) for _ in range(3)],
            max_batch=16, max_wait_ticks=2)
        _submit(svc, ds, 100)
        res = run_with_timeout(svc.run_until_drained)
        want = _expected(ds, fo, range(100))
        assert len(res) == 100
        for r in res:
            assert r.label == want[r.uid], r

    @pytest.mark.parametrize("policy", ("ws", "drr", "od", "health_ws"))
    def test_every_policy_drains(self, ds, fo, policy):
        svc = BatchPredictService(
            [InferReplica.from_forest(fo, ds.attr_is_cont) for _ in range(3)],
            policy=policy, max_batch=8, max_wait_ticks=2)
        _submit(svc, ds, 60)
        res = run_with_timeout(svc.run_until_drained)
        assert len(res) == 60 and not svc.failed

    def test_ws_spreads_batches(self, ds, fo):
        svc = BatchPredictService(
            [InferReplica.from_forest(fo, ds.attr_is_cont) for _ in range(4)],
            policy="ws", max_batch=8, max_wait_ticks=1)
        _submit(svc, ds, 160)
        res = run_with_timeout(svc.run_until_drained)
        used = {r.replica for r in res}
        assert used == {0, 1, 2, 3}


class TestFailover:
    def test_replica_death_requeues_and_drains(self, ds, fo):
        reg = Registry()
        replicas = [
            FlakyReplica.from_forest(fo, ds.attr_is_cont),
            InferReplica.from_forest(fo, ds.attr_is_cont),
        ]
        replicas[0] = FlakyReplica(replicas[0].models, fail_after=1)
        svc = BatchPredictService(replicas, max_batch=8, max_wait_ticks=1,
                                  metrics=reg)
        _submit(svc, ds, 80)
        res = run_with_timeout(svc.run_until_drained)
        # every request terminal: served (possibly after requeue) or failed
        assert len(res) + len(svc.failed) == 80
        assert len(res) == 80              # healthy replica absorbed it all
        assert svc.stats()["evicted_replicas"] == [0]
        assert reg.get("infer_evictions_total").value() == 1
        # correctness survives the failover
        want = _expected(ds, fo, range(80))
        assert all(r.label == want[r.uid] for r in res)

    def test_all_replicas_dead_fails_explicitly(self, ds, fo):
        replicas = [FlakyReplica(
            InferReplica.from_forest(fo, ds.attr_is_cont).models,
            fail_after=0)]
        svc = BatchPredictService(replicas, max_batch=8, max_wait_ticks=1)
        _submit(svc, ds, 20)
        res = run_with_timeout(svc.run_until_drained)
        assert res == []
        assert len(svc.failed) == 20
        reasons = {f.reason for f in svc.failed}
        assert reasons <= {"no_replicas", "requeue_exhausted"}

    def test_requeue_budget_is_bounded(self, ds, fo):
        """A request cannot bounce between dying replicas forever."""
        replicas = [
            FlakyReplica(InferReplica.from_forest(fo, ds.attr_is_cont).models,
                         fail_after=0),
            FlakyReplica(InferReplica.from_forest(fo, ds.attr_is_cont).models,
                         fail_after=0),
        ]
        svc = BatchPredictService(replicas, max_batch=4, max_wait_ticks=1,
                                  max_requeues=1)
        _submit(svc, ds, 12)
        run_with_timeout(svc.run_until_drained)
        assert len(svc.failed) == 12
        assert svc.stats()["healthy_replicas"] == 0

    def test_eviction_masks_physical_indices(self, ds, fo):
        """After an eviction the policy still addresses the full list."""
        replicas = [
            FlakyReplica(InferReplica.from_forest(fo, ds.attr_is_cont).models,
                         fail_after=0),
            InferReplica.from_forest(fo, ds.attr_is_cont),
            InferReplica.from_forest(fo, ds.attr_is_cont),
        ]
        svc = BatchPredictService(replicas, policy="drr", max_batch=4,
                                  max_wait_ticks=1)
        _submit(svc, ds, 40)
        res = run_with_timeout(svc.run_until_drained)
        assert {r.replica for r in res} <= {1, 2}
        assert len(res) + len(svc.failed) == 40


class TestCanaryShadow:
    def _handle(self, tmp_path, ds, rng):
        """Stable = newest publish (a deliberately degenerate depth-1 tree);
        candidate = the prior full-depth tree, so the two arms disagree."""
        full = c45.build(ds, GrowConfig())
        stump = c45.build(ds, GrowConfig(max_depth=1))
        v1 = registry.publish(str(tmp_path), "m", full)
        registry.publish(str(tmp_path), "m", stump)
        handle = registry.ModelHandle(str(tmp_path), "m")
        return handle, v1

    def test_canary_arm_served_by_canary_model(self, tmp_path, ds, rng):
        handle, cand = self._handle(tmp_path, ds, rng)
        handle.set_canary(cand, 0.5)
        svc = BatchPredictService(
            [InferReplica.from_handle(handle, ds.attr_is_cont)],
            handle=handle, max_batch=8, max_wait_ticks=1)
        _submit(svc, ds, 120)
        res = run_with_timeout(svc.run_until_drained)
        assert len(res) == 120
        by_arm = {a: [r for r in res if r.arm == a]
                  for a in ("stable", "canary")}
        assert by_arm["stable"] and by_arm["canary"]
        want_stable = _expected(ds, handle.stable, range(120))
        want_canary = _expected(ds, handle.canary, range(120))
        assert all(r.label == want_stable[r.uid] for r in by_arm["stable"])
        assert all(r.label == want_canary[r.uid] for r in by_arm["canary"])
        # routing is the handle's deterministic hash
        assert all(handle.route(r.uid) == r.arm for r in res)

    def test_shadow_mirrors_without_shifting(self, tmp_path, ds, rng):
        handle, cand = self._handle(tmp_path, ds, rng)
        handle.set_canary(cand, 0.5, shadow=True)
        reg = Registry()
        svc = BatchPredictService(
            [InferReplica.from_handle(handle, ds.attr_is_cont)],
            handle=handle, max_batch=16, max_wait_ticks=1, metrics=reg)
        _submit(svc, ds, 64)
        res = run_with_timeout(svc.run_until_drained)
        assert len(res) == 64
        assert all(r.arm == "stable" for r in res)      # no traffic shift
        assert reg.get("infer_shadow_mirrored_total").value() == 64
        # the degenerate shadow model must disagree somewhere
        assert reg.get("infer_shadow_disagree_total").value() > 0

    def test_hot_swap_reaches_replicas(self, tmp_path, ds, rng):
        handle, cand = self._handle(tmp_path, ds, rng)
        rep = InferReplica.from_handle(handle, ds.attr_is_cont)
        svc = BatchPredictService([rep], handle=handle, max_batch=8,
                                  max_wait_ticks=1)
        _submit(svc, ds, 16)
        run_with_timeout(svc.run_until_drained)
        handle.set_canary(cand, 0.0)
        handle.promote_canary()            # in-memory hot swap
        want_new = _expected(ds, handle.stable, range(16))
        svc2 = BatchPredictService([rep], handle=handle, max_batch=8,
                                   max_wait_ticks=1)
        _submit(svc2, ds, 16)
        res = run_with_timeout(svc2.run_until_drained)
        assert all(r.label == want_new[r.uid] for r in res)


class TestObservability:
    def test_metrics_and_spans(self, ds, fo):
        reg = Registry()
        tracer = Tracer(enabled=True)
        svc = BatchPredictService(
            [InferReplica.from_forest(fo, ds.attr_is_cont) for _ in range(2)],
            max_batch=8, max_wait_ticks=2, metrics=reg, tracer=tracer)
        _submit(svc, ds, 40)
        res = run_with_timeout(svc.run_until_drained)
        assert len(res) == 40
        assert reg.get("infer_requests_total").value() == 40
        assert reg.get("infer_results_total").value(arm="stable") == 40
        wait = reg.get("infer_queue_wait_ticks")._snapshot_series()[0]
        assert wait["count"] == 40
        busy = reg.get("infer_replica_batches_total")
        assert sum(s["value"] for s in busy._snapshot_series()) >= 5
        names = {e.get("name") for e in tracer._events}
        assert {"predict", "infer.tick", "infer.batch.dispatch"} <= names

    def test_accounting_identity(self, ds, fo):
        """submitted == results + failed, always (the drain contract)."""
        reg = Registry()
        replicas = [
            FlakyReplica(InferReplica.from_forest(fo, ds.attr_is_cont).models,
                         fail_after=2),
            InferReplica.from_forest(fo, ds.attr_is_cont),
        ]
        svc = BatchPredictService(replicas, max_batch=8, max_wait_ticks=1,
                                  metrics=reg)
        _submit(svc, ds, 120)
        run_with_timeout(svc.run_until_drained)
        assert len(svc.results) + len(svc.failed) == 120

    def test_replica_rejects_unknown_arm(self, ds, fo):
        rep = InferReplica.from_forest(fo, ds.attr_is_cont)
        with pytest.raises(KeyError):
            rep.admit(_Batch(arm="canary", requests=[
                PredictRequest(uid=0, x_row=ds.x[0])]))
