"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — unit tests must see the real single
CPU device; only the dry-run (and the subprocess in test_dryrun_small)
fakes a device count.
"""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_tree_dataset(rng, n=300, *, n_cont=2, n_disc=2, n_classes=2,
                      unknown_frac=0.0, max_bins=64, domain=16):
    """Random small rank-space dataset with learnable structure."""
    from repro.core import binning
    cols, kinds = [], []
    for _ in range(n_cont):
        base = rng.uniform(-2, 2, size=domain)     # small domain => exact bins
        c = rng.choice(base, size=n)
        if unknown_frac:
            c = c.copy()
            c[rng.random(n) < unknown_frac] = np.nan
        cols.append(c)
        kinds.append(True)
    for _ in range(n_disc):
        cols.append(rng.integers(0, int(rng.integers(2, 5)), n))
        kinds.append(False)
    y = rng.integers(0, n_classes, n)
    gate = np.nan_to_num(cols[0], nan=0.0) > 0
    y = np.where(gate, (y + 1) % n_classes, y)     # correlate with col 0
    return binning.fit(cols, y, attr_is_cont=kinds, n_classes=n_classes,
                       max_bins=max_bins)
