"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — unit tests must see the real single
CPU device; only the dry-run (and the subprocess in test_dryrun_small)
fakes a device count.
"""

import threading

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_with_timeout(fn, seconds=30.0):
    """Run ``fn`` on a daemon thread; fail (don't hang) if it deadlocks.

    Backstop for the fault-path tests: they must *fail* on a regression of
    the farm/engine termination guarantees even when pytest-timeout is not
    installed.  Exceptions from ``fn`` are re-raised in the caller.
    """
    box = {}

    def target():
        try:
            box["val"] = fn()
        except BaseException as e:   # pragma: no cover - surfaced below
            box["exc"] = e

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(seconds)
    if t.is_alive():
        pytest.fail(f"deadlock: call did not finish within {seconds}s")
    if "exc" in box:
        raise box["exc"]
    return box["val"]


def make_tree_dataset(rng, n=300, *, n_cont=2, n_disc=2, n_classes=2,
                      unknown_frac=0.0, max_bins=64, domain=16):
    """Random small rank-space dataset with learnable structure."""
    from repro.core import binning
    cols, kinds = [], []
    for _ in range(n_cont):
        base = rng.uniform(-2, 2, size=domain)     # small domain => exact bins
        c = rng.choice(base, size=n)
        if unknown_frac:
            c = c.copy()
            c[rng.random(n) < unknown_frac] = np.nan
        cols.append(c)
        kinds.append(True)
    for _ in range(n_disc):
        cols.append(rng.integers(0, int(rng.integers(2, 5)), n))
        kinds.append(False)
    y = rng.integers(0, n_classes, n)
    gate = np.nan_to_num(cols[0], nan=0.0) > 0
    y = np.where(gate, (y + 1) % n_classes, y)     # correlate with col 0
    return binning.fit(cols, y, attr_is_cont=kinds, n_classes=n_classes,
                       max_bins=max_bins)
