"""AdamW-from-scratch unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as opt


def test_first_step_matches_hand_computation():
    cfg = opt.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                          weight_decay=0.0, clip_norm=1e9,
                          warmup_steps=0, total_steps=1, min_lr_ratio=1.0)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    m, v = opt.init_moments(p)
    p2, m2, v2, stats = opt.adamw_update(g, m, v, p, jnp.int32(0), cfg)
    # bias-corrected first step = -lr * g/|g| elementwise == -lr * sign(g)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(p["w"]) - 0.1 * np.sign([0.5, 0.5]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2["w"]), [0.05, 0.05], atol=1e-7)


def test_weight_decay_pulls_to_zero():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                          total_steps=1, min_lr_ratio=1.0, clip_norm=1e9)
    p = {"w": jnp.array([4.0])}
    g = {"w": jnp.array([0.0])}
    m, v = opt.init_moments(p)
    p2, *_ = opt.adamw_update(g, m, v, p, jnp.int32(0), cfg)
    assert float(p2["w"][0]) == pytest.approx(4.0 - 0.1 * 0.5 * 4.0)


def test_clip_norm_applied():
    cfg = opt.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                          warmup_steps=0, total_steps=1, min_lr_ratio=1.0)
    g = {"w": jnp.array([3.0, 4.0])}     # norm 5 -> scaled by 1/5
    p = {"w": jnp.zeros(2)}
    m, v = opt.init_moments(p)
    _, m2, _, stats = opt.adamw_update(g, m, v, p, jnp.int32(0), cfg)
    assert float(stats["grad_norm"]) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(m2["w"]),
                               0.1 * np.array([0.6, 0.8]), atol=1e-6)


def test_lr_schedule_warmup_then_cosine():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_ratio=0.1)
    assert float(opt.lr_at(cfg, jnp.int32(0))) == 0.0
    assert float(opt.lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(opt.lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(opt.lr_at(cfg, jnp.int32(110))) == pytest.approx(0.1)
    mid = float(opt.lr_at(cfg, jnp.int32(60)))
    assert 0.1 < mid < 1.0


def test_bf16_params_get_f32_master_update():
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=1,
                          min_lr_ratio=1.0, weight_decay=0.0)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    m, v = opt.init_moments(p)
    assert m["w"].dtype == jnp.float32
    p2, m2, v2, _ = opt.adamw_update(g, m, v, p, jnp.int32(0), cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert m2["w"].dtype == jnp.float32


def test_structural_tuples_in_tree():
    """Param trees with tuples (the transformer layout) must round-trip."""
    cfg = opt.AdamWConfig(warmup_steps=0, total_steps=1)
    p = {"scan": ({"a": jnp.ones(2)}, {"b": jnp.ones(3)}), "c": jnp.ones(1)}
    g = jax.tree.map(jnp.ones_like, p)
    m, v = opt.init_moments(p)
    p2, m2, v2, _ = opt.adamw_update(g, m, v, p, jnp.int32(0), cfg)
    assert jax.tree.structure(p2) == jax.tree.structure(p)


def test_grad_accumulation_equivalence():
    """grad_accum=2 over a batch == one step on the full batch."""
    from repro.train.train_step import init_state, make_train_step
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(0, 1, (4, 3)), jnp.float32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"loss": loss}

    batch = {"x": jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32),
             "y": jnp.asarray(rng.normal(0, 1, (8, 3)), jnp.float32)}
    cfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=2,
                          min_lr_ratio=1.0)
    s1 = init_state({"w": w0})
    s2 = init_state({"w": w0})
    s1, _ = make_train_step(loss_fn, cfg, grad_accum=1)(s1, batch)
    s2, _ = make_train_step(loss_fn, cfg, grad_accum=2)(s2, batch)
    # MSE-mean loss: accumulated mean-of-means == full-batch mean here
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]), atol=1e-5)
