"""The unrollable scan must be semantics-identical to lax.scan."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import scan as uscan


def _f(c, x):
    return c + x["a"] * 2, {"y": c * x["a"], "z": x["b"] + 1}


def test_matches_lax_scan():
    xs = {"a": jnp.arange(5.0), "b": jnp.ones((5, 3))}
    c1, y1 = jax.lax.scan(_f, jnp.float32(0), xs)
    with uscan.unrolled():
        c2, y2 = uscan.scan(_f, jnp.float32(0), xs)
    assert float(c1) == float(c2)
    for k in y1:
        np.testing.assert_allclose(np.asarray(y1[k]), np.asarray(y2[k]))


def test_none_ys():
    def f(c, x):
        return c + x, None
    with uscan.unrolled():
        c, ys = uscan.scan(f, jnp.float32(0), jnp.arange(4.0))
    assert ys is None and float(c) == 6.0


def test_length_only():
    def f(c, _):
        return c * 2, c
    with uscan.unrolled():
        c, ys = uscan.scan(f, jnp.float32(1), None, length=3)
    assert float(c) == 8.0
    np.testing.assert_allclose(np.asarray(ys), [1, 2, 4])


def test_analysis_chunk():
    assert uscan.analysis_chunk(512, 4096) == 512          # not unrolled
    with uscan.unrolled():
        assert uscan.analysis_chunk(512, 32768) == 4096    # 8 blocks
        assert uscan.analysis_chunk(512, 1024) == 512      # already small


def test_model_forward_invariant_under_unroll():
    """Full reduced model: scanned == unrolled forward (the property the
    roofline accounting relies on)."""
    from repro.configs import base as cfgbase
    from repro.models.model import build_model
    cfg = cfgbase.reduced(cfgbase.get_config("gemma2_9b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = dict(tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64))),
                 labels=jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64))))
    l1, _ = model.loss_fn(params, batch)
    with uscan.unrolled():
        l2, _ = model.loss_fn(params, batch)
    assert abs(float(l1) - float(l2)) < 2e-3
