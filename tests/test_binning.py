"""Direct unit tests for the rank-space binner's degenerate cases.

``_bin_continuous`` feeds every engine; these pin the corner behaviours the
property tests only hit by accident: constant columns, all-unknown columns,
``max_bins=1``, and skewed distributions whose quantile cuts collapse onto
duplicate edges.
"""

import numpy as np
import pytest

from repro.core import binning
from repro.core.binning import UNKNOWN, _bin_continuous


class TestBinContinuousDegenerate:
    def test_constant_column_single_exact_bin(self):
        col = np.full(50, 3.25)
        b, edges = _bin_continuous(col, max_bins=8)
        assert (b == 0).all()
        np.testing.assert_array_equal(edges, [3.25])

    def test_all_unknown_column(self):
        col = np.full(20, np.nan)
        b, edges = _bin_continuous(col, max_bins=8)
        assert (b == UNKNOWN).all()
        assert edges.shape == (0,)

    def test_all_unknown_column_survives_fit(self):
        """An all-unknown attribute must fit cleanly and never split."""
        rng = np.random.default_rng(0)
        n = 80
        cols = [rng.uniform(-1, 1, n), np.full(n, np.nan)]
        y = (cols[0] > 0).astype(np.int64)
        ds = binning.fit(cols, y, attr_is_cont=[True, True], n_classes=2,
                         max_bins=16)
        assert ds.n_bins[1] == 1          # fit clamps to >=1 for histograms
        assert ds.bin_edges[1].size == 0  # ...but there is no real edge
        assert (np.asarray(ds.x)[:, 1] == UNKNOWN).all()
        from repro.core import c45
        from repro.core.config import GrowConfig
        tree = c45.build(ds, GrowConfig())
        used = np.asarray(tree.node_attr)[:tree.size]
        assert 1 not in set(used[used >= 0].tolist())

    def test_max_bins_one_degenerates_to_single_bin(self):
        col = np.linspace(-5.0, 5.0, 100)
        b, edges = _bin_continuous(col, max_bins=1)
        assert (b == 0).all()
        np.testing.assert_array_equal(edges, [5.0])

    def test_max_bins_below_one_rejected(self):
        with pytest.raises(ValueError, match="max_bins"):
            _bin_continuous(np.ones(3), max_bins=0)
        with pytest.raises(ValueError, match="max_bins"):
            _bin_continuous(np.ones(3), max_bins=-2)

    def test_skewed_quantiles_do_not_duplicate_edges(self):
        # 97% of the mass on the domain max: most quantile cuts collapse onto
        # it; edges must stay strictly increasing with no empty trailing bin.
        col = np.concatenate([np.arange(30, dtype=float),
                              np.full(1000, 29.0)])
        b, edges = _bin_continuous(col, max_bins=8)
        assert np.unique(edges).size == edges.size
        assert (np.diff(edges) > 0).all()
        assert b.max() == edges.size - 1
        # every bin actually holds at least one case
        assert np.bincount(b, minlength=edges.size).min() > 0

    def test_edges_are_domain_values(self):
        rng = np.random.default_rng(1)
        col = rng.lognormal(size=500)
        _, edges = _bin_continuous(col, max_bins=16)
        assert np.isin(edges, np.unique(col)).all()

    def test_split_threshold_includes_its_edge(self):
        # side="left" contract: a value equal to edge[i] lands in bin i, so
        # the split "x <= threshold_value(a, i)" keeps its own edge value.
        col = np.repeat(np.arange(100, dtype=float), 5)
        b, edges = _bin_continuous(col, max_bins=10)
        for i, e in enumerate(edges):
            assert b[np.flatnonzero(col == e)[0]] == i

    def test_unknowns_mixed_with_known_values(self):
        col = np.array([1.0, np.nan, 2.0, np.nan, 1.0])
        b, edges = _bin_continuous(col, max_bins=4)
        np.testing.assert_array_equal(b, [0, UNKNOWN, 1, UNKNOWN, 0])
        np.testing.assert_array_equal(edges, [1.0, 2.0])
