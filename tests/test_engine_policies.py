"""Serving-policy regressions: policy-by-name, OD capacity, DRR failover.

Uses a model-free ``FakeReplica`` implementing the engine's replica
interface, so scheduling semantics are tested without jax in the loop:

  * every documented policy name (``drr | od | ws | health_ws``) must
    construct and drain (``make_policy`` used to reject ``health_ws``);
  * ``od`` must honor ``Policy.forced_capacity``: at most one *newly
    queued* request per replica per tick (the engine used to hand the
    policy ``cap=n_slots`` views, degenerating OD to DRR over full slot
    batches);
  * DRR round-robin state must address *physical* replicas across an
    eviction (the engine used to let ``DRR._next`` index a filtered
    healthy-only list, silently shifting the rotation after a failover).
"""

import pytest

from repro.obs.metrics import Registry
from repro.obs.trace import Tracer
from repro.serve.engine import Completion, Request, ServingEngine

import numpy as np


class FakeReplica:
    """Slot semantics without a model: each request decodes one token/tick."""

    def __init__(self, n_slots=4):
        self.n_slots = n_slots
        self.slots = {}                 # uid -> remaining ticks
        self.admissions = []            # uids in admission order

    def queue_len(self):
        return len(self.slots)

    def queued_weight(self):
        return float(sum(self.slots.values()))

    def capacity(self):
        return self.n_slots

    def active_uids(self):
        return list(self.slots)

    def release(self, uid):
        self.slots.pop(uid, None)
        return []

    def admit(self, req):
        if len(self.slots) >= self.n_slots:
            raise RuntimeError("no free slot (scheduler race)")
        self.slots[req.uid] = max(int(req.max_new_tokens), 1)
        self.admissions.append(req.uid)

    def tick(self):
        done = []
        for uid in list(self.slots):
            self.slots[uid] -= 1
            if self.slots[uid] <= 0:
                del self.slots[uid]
                done.append(Completion(uid, [0]))
        return done


def _req(uid, weight=4):
    return Request(uid=uid, prompt=np.zeros(1, np.int32),
                   max_new_tokens=weight)


@pytest.mark.parametrize("policy", ["drr", "od", "ws", "health_ws"])
def test_engine_accepts_every_documented_policy_name(policy):
    reps = [FakeReplica(), FakeReplica()]
    eng = ServingEngine(reps, policy=policy)
    for i in range(6):
        eng.submit(_req(i))
    done = eng.run_until_drained(max_ticks=200)
    assert sorted(c.uid for c in done) == list(range(6))
    assert eng.failed == []


def test_health_ws_speed_fn_hook_steers_admissions():
    reps = [FakeReplica(8), FakeReplica(8)]
    eng = ServingEngine(reps, policy="health_ws",
                        speed_fn=lambda: {0: 0.0, 1: 1.0})
    for i in range(4):
        eng.submit(_req(i))
    eng._admit_backlog()
    assert reps[0].admissions == []          # speed 0 = do not schedule
    assert reps[1].admissions == [0, 1, 2, 3]


def test_od_admits_at_most_one_per_replica_per_tick():
    reps = [FakeReplica(4), FakeReplica(4)]
    eng = ServingEngine(reps, policy="od")
    for i in range(8):
        eng.submit(_req(i))
    eng._admit_backlog()                     # one scheduling round = one tick
    assert [len(r.admissions) for r in reps] == [1, 1]
    done = eng.run_until_drained(max_ticks=200)
    assert sorted(c.uid for c in done) == list(range(8))
    # OD never outran its per-tick budget: admissions stay <= 1 per call
    assert eng.failed == []


def test_od_respects_free_slots():
    rep = FakeReplica(n_slots=1)
    eng = ServingEngine([rep], policy="od")
    eng.submit(_req(0, weight=3))
    eng.submit(_req(1, weight=3))
    eng._admit_backlog()
    assert rep.admissions == [0]             # slot full: uid 1 must wait
    eng._admit_backlog()
    assert rep.admissions == [0]             # still full, even a fresh round
    done = eng.run_until_drained(max_ticks=100)
    assert sorted(c.uid for c in done) == [0, 1]


def test_drr_rotation_addresses_physical_replicas_after_eviction():
    reps = [FakeReplica(8) for _ in range(3)]
    eng = ServingEngine(reps, policy="drr")
    eng.submit(_req(0))
    eng.submit(_req(1))
    eng._admit_backlog()                     # DRR: -> r0, r1; _next points at 2
    assert (reps[0].admissions, reps[1].admissions) == ([0], [1])
    eng._evict(0, "test")                    # requeues uid 0 into the backlog
    eng.submit(_req(2))
    eng._admit_backlog()
    # The rotation pointer meant *physical* replica 2.  Before the fix the
    # policy saw the filtered healthy list [r1, r2], so _next=2 wrapped to
    # index 0 of that list and the requeued request landed back-to-back on
    # r1 while r2 sat idle.
    assert reps[2].admissions == [0]         # requeued uid 0 -> physical r2
    assert reps[1].admissions == [1, 2]      # then rotation skips dead r0


def test_drr_stays_fair_across_eviction():
    reps = [FakeReplica(16) for _ in range(3)]
    eng = ServingEngine(reps, policy="drr")
    eng._evict(1, "test")
    for i in range(8):
        eng.submit(_req(i))
    eng._admit_backlog()
    assert reps[1].admissions == []
    assert len(reps[0].admissions) == 4 and len(reps[2].admissions) == 4


def test_engine_drain_produces_trace_and_metrics():
    tr = Tracer()
    reg = Registry()
    reps = [FakeReplica(2), FakeReplica(2)]
    eng = ServingEngine(reps, policy="ws", tracer=tr, metrics=reg)
    for i in range(5):
        eng.submit(_req(i, weight=3))
    eng.run_until_drained(max_ticks=100)

    names = {e["name"] for e in tr.events}
    assert {"engine.tick", "request", "request.admit"} <= names
    # every request's async span is closed exactly once
    begins = [e for e in tr.events if e.get("ph") == "b"]
    ends = [e for e in tr.events if e.get("ph") == "e"]
    assert len(begins) == 5 and len(ends) == 5
    snap = reg.snapshot()
    assert snap["engine_requests_total"]["series"][0]["value"] == 5
    assert snap["engine_completions_total"]["series"][0]["value"] == 5
    wait = snap["engine_queue_wait_ticks"]["series"][0]
    assert wait["count"] == 5
    lat = snap["engine_request_ticks"]["series"][0]
    assert lat["count"] == 5


def test_eviction_records_event_and_metric():
    tr = Tracer()
    reg = Registry()
    reps = [FakeReplica(2), FakeReplica(2)]
    eng = ServingEngine(reps, policy="ws", tracer=tr, metrics=reg)
    eng.submit(_req(0))
    eng._admit_backlog()
    victim = next(i for i, r in enumerate(reps) if r.admissions)
    eng._evict(victim, "test kill")
    assert any(e["name"] == "replica.evict" for e in tr.events)
    assert reg.snapshot()["engine_evictions_total"]["series"][0]["value"] == 1
    eng.run_until_drained(max_ticks=100)
    assert sorted(c.uid for c in eng.completed) == [0]   # requeued + finished
