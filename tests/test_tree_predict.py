"""Prediction-side semantics of the shared Tree: unknown-value routing on
wide splits (the old bounded 8-child window) and true-leaf descent depth
(the old fixed max_depth=64 truncation)."""

import numpy as np
import pytest

from repro.core import binning, c45
from repro.core.config import GrowConfig
from repro.core.tree import Tree, heavy_child_table, predict


def _chain_tree(depth: int, n_classes: int = 2) -> Tree:
    """Degenerate right-leaning chain: internal at every level, the deepest
    leaf classifies 1, every other node classifies 0."""
    import jax.numpy as jnp
    n = 2 * depth + 1
    t = Tree.empty(n, n_classes)
    attr = np.full(n, -1, np.int32)
    sbin = np.full(n, -1, np.int32)
    child0 = np.zeros(n, np.int32)
    nchild = np.zeros(n, np.int32)
    cls = np.zeros(n, np.int32)
    freq = np.zeros((n, n_classes), np.float32)
    dep = np.zeros(n, np.int32)
    node = 0
    for d in range(depth):
        attr[node] = 0
        sbin[node] = 0                     # bin 0 -> left leaf, bin 1 -> on
        child0[node] = node + 1
        nchild[node] = 2
        dep[node + 1] = dep[node + 2] = d + 1
        freq[node + 1] = [1.0, 0.0]
        freq[node + 2] = [0.0, 2.0]        # right child is heavier
        node = node + 2
    cls[node] = 1                          # the deepest leaf
    freq[0] = [1.0, 2.0]
    return Tree(
        node_attr=jnp.asarray(attr), node_split_bin=jnp.asarray(sbin),
        node_child0=jnp.asarray(child0), node_nchild=jnp.asarray(nchild),
        node_class=jnp.asarray(cls), node_freq=jnp.asarray(freq),
        node_depth=jnp.asarray(dep), n_nodes=jnp.int32(n))


def _wide_dataset(heavy_value: int, n_values: int = 12, per_value: int = 4,
                  heavy_extra: int = 30):
    """One discrete attribute with ``n_values`` categories; category
    ``heavy_value`` dominates by case count and has its own class."""
    xs, ys = [], []
    for v in range(n_values):
        reps = per_value + (heavy_extra if v == heavy_value else 0)
        xs += [v] * reps
        ys += [v % 2 if v != heavy_value else 1] * reps
    x = np.array(xs)
    y = np.array(ys)
    return binning.fit([x], y, attr_is_cont=[False], n_classes=2)


class TestHeavyChildTable:
    def test_matches_numpy_argmax_any_arity(self, rng):
        """Oracle check on random wide trees (well beyond the old window)."""
        for trial in range(5):
            m = 64
            nchild = np.zeros(m, np.int32)
            child0 = np.zeros(m, np.int32)
            # random BFS-shaped forest of sibling blocks over [1, m)
            nxt, emit = 1, 0
            while nxt < m - 1 and emit < m:
                width = int(rng.integers(2, 14))
                width = min(width, m - nxt)
                if width < 2:
                    break
                nchild[emit] = width
                child0[emit] = nxt
                nxt += width
                emit += 1
            freq = rng.random((m, 3)).astype(np.float32)
            got = np.asarray(heavy_child_table(child0, nchild, freq))
            w = freq.sum(-1)
            for i in range(m):
                if nchild[i] == 0:
                    assert got[i] == 0
                else:
                    sib = w[child0[i]: child0[i] + nchild[i]]
                    assert got[i] == int(np.argmax(sib)), (trial, i)

    def test_wide_split_unknown_routes_to_heavy_child(self):
        """An unknown value on a 12-way split must follow the heaviest
        child even when its sibling rank is past the old max_h=8 window."""
        for heavy in (1, 10, 11):
            ds = _wide_dataset(heavy)
            tree = c45.build(ds, GrowConfig(min_objs=1.0))
            t = tree.to_numpy()
            assert int(t.node_nchild[0]) == 12
            heavy_rank = int(np.asarray(heavy_child_table(
                tree.node_child0, tree.node_nchild, tree.node_freq))[0])
            assert heavy_rank == heavy
            unknown = np.array([[-1]], np.int32)
            pred = int(np.asarray(predict(tree, unknown,
                                          ds.attr_is_cont))[0])
            heavy_leaf = int(t.node_child0[0]) + heavy
            assert pred == int(t.node_class[heavy_leaf]) == 1

    def test_oracle_agreement_with_unknowns(self, rng):
        """predict on unknown-valued cases == the C4.5 heaviest-child oracle
        (sequential build routes training unknowns the same way)."""
        from conftest import make_tree_dataset
        ds = make_tree_dataset(rng, n=500, unknown_frac=0.2)
        tree = c45.build(ds, GrowConfig())
        t = tree.to_numpy()

        def oracle_one(row):
            node = 0
            while t.node_nchild[node]:
                a = int(t.node_attr[node])
                b = int(row[a])
                if b < 0:
                    w = t.node_freq.sum(-1)
                    sib = w[t.node_child0[node]:
                            t.node_child0[node] + t.node_nchild[node]]
                    child = int(np.argmax(sib))
                elif ds.attr_is_cont[a]:
                    child = 0 if b <= int(t.node_split_bin[node]) else 1
                else:
                    child = min(b, int(t.node_nchild[node]) - 1)
                node = int(t.node_child0[node]) + child
            return int(t.node_class[node])

        pred = np.asarray(predict(tree, ds.x, ds.attr_is_cont))
        want = np.array([oracle_one(r) for r in ds.x])
        np.testing.assert_array_equal(pred, want)


class TestPredictDepth:
    def test_deep_tree_classifies_at_true_leaf(self):
        """Default descent must reach leaves deeper than the old fixed 64."""
        depth = 100
        tree = _chain_tree(depth)
        assert tree.depth == depth
        x = np.array([[1]], np.int32)      # bin 1: always go right
        pred = int(np.asarray(predict(tree, x, np.array([True])))[0])
        assert pred == 1                   # the depth-100 leaf's class
        # explicit truncation stays available for jit-static callers
        trunc = int(np.asarray(predict(tree, x, np.array([True]),
                                       max_depth=10))[0])
        assert trunc == 0                  # parked at an internal node

    def test_default_depth_matches_explicit(self, rng):
        from conftest import make_tree_dataset
        ds = make_tree_dataset(rng, n=300)
        tree = c45.build(ds, GrowConfig())
        a = np.asarray(predict(tree, ds.x, ds.attr_is_cont))
        b = np.asarray(predict(tree, ds.x, ds.attr_is_cont,
                               max_depth=tree.depth + 1))
        np.testing.assert_array_equal(a, b)

    def test_empty_tree_depth_default(self):
        tree = Tree.empty(4, 2)
        pred = predict(tree, np.zeros((3, 1), np.int32), np.array([True]))
        np.testing.assert_array_equal(np.asarray(pred), np.zeros(3))
