"""Property-test front-end: real hypothesis when installed, else a fallback.

The tier-1 suite must collect and pass in hermetic containers where
``hypothesis`` cannot be installed (see requirements-dev.txt for the full
dev environment).  When the import fails, this module provides a small
deterministic stand-in implementing the subset of the API our tests use:

  * ``st.integers / floats / sampled_from / lists / permutations / data``
  * ``@given(...)`` with positional (right-aligned, hypothesis rules) or
    keyword strategies
  * ``@settings(max_examples=..., deadline=...)``

Examples are drawn from a per-example seeded ``numpy`` Generator, so runs
are reproducible (no shrinking, no example database — this is a coverage
fallback, not a hypothesis replacement).
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as np

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class _DataStrategy(_Strategy):
        """Marker for ``st.data()`` — materialised per example by @given."""

        def __init__(self):
            super().__init__(None)

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            pool = list(elements)
            return _Strategy(lambda rng: pool[int(rng.integers(len(pool)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = min_size + 8 if max_size is None else max_size

            def draw(rng):
                size = int(rng.integers(min_size, hi + 1))
                return [elements.draw(rng) for _ in range(size)]

            return _Strategy(draw)

        @staticmethod
        def permutations(values):
            pool = list(values)
            return _Strategy(
                lambda rng: [pool[i] for i in rng.permutation(len(pool))])

        @staticmethod
        def data():
            return _DataStrategy()

    strategies = st

    def _draw(strategy, rng):
        if isinstance(strategy, _DataStrategy):
            return _DataObject(rng)
        return strategy.draw(rng)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            # hypothesis maps positional strategies to the *rightmost*
            # parameters (so methods' ``self`` stays free)
            n_pos = len(arg_strategies)
            pos_names = ([p.name for p in params[len(params) - n_pos:]]
                         if n_pos else [])
            provided = set(pos_names) | set(kw_strategies)

            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_fallback_max_examples", 20)
                for example in range(n):
                    rng = np.random.default_rng(0xC45 + example)
                    drawn = {name: _draw(s, rng)
                             for name, s in zip(pos_names, arg_strategies)}
                    drawn.update({k: _draw(s, rng)
                                  for k, s in kw_strategies.items()})
                    fn(*args, **kwargs, **drawn)

            functools.update_wrapper(wrapper, fn)
            # pytest must not see the strategy-filled params as fixtures
            wrapper.__signature__ = sig.replace(parameters=[
                p for p in params if p.name not in provided])
            return wrapper

        return deco
