"""Semantics tests for the sequential YaDT oracle."""

import numpy as np
import pytest

from repro.core import binning, c45
from repro.core.config import GrowConfig
from repro.core.tree import predict


def _build(cols, y, kinds, cfg=GrowConfig(), **kw):
    ds = binning.fit(cols, y, attr_is_cont=kinds, **kw)
    return ds, c45.build(ds, cfg)


def test_pure_root_is_leaf():
    ds, tree = _build([np.array([1.0, 2.0, 3.0, 4.0])],
                      np.zeros(4, int), [True], n_classes=2)
    assert tree.size == 1 and tree.n_leaves == 1
    assert int(np.asarray(tree.node_class)[0]) == 0


def test_single_continuous_split():
    x = np.array([1.0, 2.0, 3.0, 10.0, 11.0, 12.0])
    y = np.array([0, 0, 0, 1, 1, 1])
    ds, tree = _build([x], y, [True])
    t = tree.to_numpy()
    assert int(t.node_attr[0]) == 0
    # threshold must be a value of the WHOLE training set below the midpoint
    thr = ds.threshold_value(0, int(t.node_split_bin[0]))
    assert thr == 3.0                     # largest value <= (3+10)/2
    pred = np.asarray(predict(tree, ds.x, ds.attr_is_cont))
    assert (pred == y).all()


def test_discrete_split_children_per_domain_value():
    x = np.array([0, 0, 1, 1, 2, 2])
    y = np.array([0, 0, 1, 1, 0, 0])
    ds, tree = _build([x], y, [False])
    t = tree.to_numpy()
    assert int(t.node_attr[0]) == 0
    assert int(t.node_nchild[0]) == 3     # one child per domain value
    pred = np.asarray(predict(tree, ds.x, ds.attr_is_cont))
    assert (pred == y).all()


def test_discrete_attr_consumed_in_subtree():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2, 200)
    b = rng.integers(0, 3, 200)
    y = (a ^ (b == 1)).astype(int)
    ds, tree = _build([a, b], y, [False, False])
    t = tree.to_numpy()
    # no node may test the same discrete attribute as any ancestor
    def walk(i, used):
        attr = int(t.node_attr[i])
        if attr < 0:
            return
        assert attr not in used
        for j in range(int(t.node_nchild[i])):
            walk(int(t.node_child0[i]) + j, used | {attr})
    walk(0, set())


def test_min_objs_stop():
    x = np.array([1.0, 2.0, 3.0])
    y = np.array([0, 1, 0])
    cfg = GrowConfig(min_objs=2.0)        # 3 < 2*min_objs => leaf
    ds, tree = _build([x], y, [True], cfg)
    assert tree.size == 1


def test_unknown_fractional_weights():
    # known cases split perfectly; one unknown case spreads over children
    x = np.array([1.0, 1.0, 1.0, 5.0, 5.0, 5.0, np.nan, np.nan])
    y = np.array([0, 0, 0, 1, 1, 1, 0, 1])
    cfg = GrowConfig(unknown_fractional=True)
    ds, tree = _build([x], y, [True], cfg)
    t = tree.to_numpy()
    assert int(t.node_attr[0]) == 0
    c0, c1 = int(t.node_child0[0]), int(t.node_child0[0]) + 1
    # each child got 3 known cases + 2 unknowns at weight 3/6 each
    assert t.node_freq[c0].sum() == pytest.approx(4.0, abs=1e-5)
    assert t.node_freq[c1].sum() == pytest.approx(4.0, abs=1e-5)


def test_unknown_heaviest_routing():
    x = np.array([1.0, 1.0, 1.0, 1.0, 5.0, 5.0, np.nan])
    y = np.array([0, 0, 0, 0, 1, 1, 1])
    cfg = GrowConfig(unknown_fractional=False, min_objs=1.0)
    ds, tree = _build([x], y, [True], cfg)
    t = tree.to_numpy()
    c0 = int(t.node_child0[0])
    # unknown went to the heavier (left) child with full weight
    assert t.node_freq[c0].sum() == pytest.approx(5.0, abs=1e-5)


def test_task_trace_records_dag():
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, 400)
    d = rng.integers(0, 3, 400)
    y = ((x > 0.5) ^ (d == 1)).astype(int)
    ds = binning.fit([x, d], y, attr_is_cont=[True, False])
    trace = []
    tree = c45.build(ds, GrowConfig(), task_trace=trace)
    assert len(trace) == tree.size
    roots = [t for t in trace if t["parent"] < 0]
    assert len(roots) == 1 and roots[0]["r"] == 400
    internal = sum(1 for t in trace if t["n_children"] > 0)
    assert internal == tree.size - tree.n_leaves


def test_gain_ratio_criterion_builds():
    rng = np.random.default_rng(2)
    x = rng.uniform(0, 1, 300)
    y = (x > 0.4).astype(int)
    ds = binning.fit([x], y, attr_is_cont=[True])
    tree = c45.build(ds, GrowConfig(criterion="gain_ratio"))
    pred = np.asarray(predict(tree, ds.x, ds.attr_is_cont))
    assert (pred == y).mean() > 0.95
