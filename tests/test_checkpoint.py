"""Checkpoint save/restore: atomicity, integrity, mesh-agnosticism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _state():
    return {"params": {"scan": ({"w": jnp.arange(6.0).reshape(2, 3)},),
                       "embed": jnp.ones((4, 2), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    s = _state()
    path = ckpt.save(str(tmp_path), 7, s)
    like = jax.tree.map(lambda a: jnp.zeros_like(a), s)
    r = ckpt.restore(path, like)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.manifest_step(path) == 7


def test_latest_valid_ordering(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 1, s)
    ckpt.save(str(tmp_path), 10, s)
    ckpt.save(str(tmp_path), 5, s)
    assert ckpt.latest_valid(str(tmp_path)).endswith("step_0000000010")


def test_corruption_detected_and_skipped(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 1, s)
    p2 = ckpt.save(str(tmp_path), 2, s)
    # corrupt the newest checkpoint: torn write on one leaf
    victim = [f for f in os.listdir(p2) if f.endswith(".npy")][0]
    with open(os.path.join(p2, victim), "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\xff\xff\xff\xff")
    assert not ckpt.verify(p2)
    latest = ckpt.latest_valid(str(tmp_path))
    assert latest.endswith("step_0000000001")   # falls back to valid one


def test_shape_mismatch_raises(tmp_path):
    s = _state()
    path = ckpt.save(str(tmp_path), 1, s)
    bad = jax.tree.map(lambda a: jnp.zeros((9, 9)), s)
    with pytest.raises(ValueError):
        ckpt.restore(path, bad)


def test_async_save_lands(tmp_path):
    import time
    s = _state()
    ckpt.save(str(tmp_path), 3, s, blocking=False)
    for _ in range(100):
        if ckpt.latest_valid(str(tmp_path)):
            break
        time.sleep(0.05)
    assert ckpt.latest_valid(str(tmp_path)).endswith("step_0000000003")


def test_async_save_handle_reraises_writer_errors(tmp_path, monkeypatch):
    """blocking=False errors must surface via wait(), not vanish."""
    s = _state()

    def boom(*a, **k):
        raise RuntimeError("disk full")

    monkeypatch.setattr(ckpt.np, "save", boom)
    handle = ckpt.save(str(tmp_path), 1, s, blocking=False)
    with pytest.raises(RuntimeError, match="disk full"):
        handle.wait(timeout=30)
    assert handle.done
    assert ckpt.latest_valid(str(tmp_path)) is None   # nothing half-landed


def test_async_save_handle_is_pathlike(tmp_path):
    s = _state()
    handle = ckpt.save(str(tmp_path), 4, s, blocking=False)
    assert handle.wait(timeout=30).endswith("step_0000000004")
    assert os.path.isdir(handle)            # usable as a plain path string
    assert handle.done
    assert ckpt.verify(handle)


def test_latest_valid_gc_collects_stale_tmp_dirs(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 1, s)
    # a crashed writer's leftover: tmp dir that never reached os.replace
    stale = tmp_path / "tmp.9.1234.0"
    stale.mkdir()
    (stale / "leaf.npy").write_bytes(b"partial")
    old = 1.0                               # epoch 1970: definitely stale
    os.utime(stale, (old, old))
    fresh = tmp_path / "tmp.10.1234.1"      # a live writer: must survive
    fresh.mkdir()
    latest = ckpt.latest_valid(str(tmp_path))
    assert latest.endswith("step_0000000001")
    assert not stale.exists()
    assert fresh.exists()


def test_train_resume_equivalence(tmp_path):
    """Train 4 steps straight == train 2, crash, resume, train 2 more."""
    from repro.launch.train import train
    out_a = train("yi_6b", reduced=True, steps=4, global_batch=2,
                  seq_len=32, ckpt_dir=None, log_every=100)
    ck = str(tmp_path / "ck")
    train("yi_6b", reduced=True, steps=2, global_batch=2, seq_len=32,
          ckpt_dir=ck, ckpt_every=2, log_every=100)
    out_b = train("yi_6b", reduced=True, steps=4, global_batch=2,
                  seq_len=32, ckpt_dir=ck, ckpt_every=10, log_every=100)
    assert out_b["last_loss"] == pytest.approx(out_a["last_loss"], abs=2e-2)
